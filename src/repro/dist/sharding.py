"""Logical-axis sharding rules with divisibility fallback.

Model / optimizer / cache code names tensor dimensions logically ("embed",
"heads", "batch", ...); this module maps logical names onto mesh axes:

  * each logical name carries an ordered list of *candidates* (tuples of
    mesh axes, so "batch" can span ("pod", "data") on multi-pod meshes);
  * a candidate is taken only if every mesh axis exists, the dimension is
    divisible by the candidate's total size, and no axis in it is already
    used by an earlier dimension of the same spec (no double-booking);
  * otherwise the next candidate is tried, and with none left the
    dimension replicates.

The fallback is what makes one model definition valid on every mesh the
elastic-rescale path moves it across: a head count that does not divide
the model axis silently degrades to replication instead of erroring.
Per-config overrides (`ModelConfig.logical_overrides`) merge over the
defaults, with the same candidate format.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# FSDP + TP defaults: batch/embed spread over the data dimension(s), the
# contraction-heavy weight dims over the tensor-parallel model axis.
DEFAULT_RULES = {
    "batch": (("pod", "data"), ("data",)),
    "embed": (("data",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "vocab": (("model",),),
    "ffn": (("model",),),
    "experts": (("model",),),
    "seq_shard": (("model",),),
}


def _candidates(rule) -> list:
    """Normalize a rule value into a list of mesh-axis tuples."""
    if rule is None:
        return []
    if isinstance(rule, str):
        return [(rule,)]
    out = []
    for cand in rule:
        out.append((cand,) if isinstance(cand, str) else tuple(cand))
    return out


def spec_for(mesh, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             rules: Optional[dict] = None) -> P:
    """PartitionSpec for a tensor with the given logical axes.

    `shape` enables the divisibility check (omit it to trust the caller);
    `rules` are per-call overrides merged over DEFAULT_RULES.
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if hasattr(mesh, "devices") else dict(mesh.shape)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    used: set = set()
    entries = []
    for i, name in enumerate(logical):
        dim = None if shape is None else int(shape[i])
        chosen = None
        if name is not None:
            for cand in _candidates(merged.get(name)):
                if not all(a in mesh_shape for a in cand):
                    continue
                if any(a in used for a in cand):
                    continue
                size = math.prod(mesh_shape[a] for a in cand)
                if dim is not None and (size == 0 or dim % size != 0):
                    continue
                chosen = cand
                break
        if chosen is None:
            entries.append(None)
        else:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    while entries and entries[-1] is None:   # trailing dims replicate anyway
        entries.pop()
    return P(*entries)


def constrain(x: jax.Array, mesh, logical: Sequence[Optional[str]],
              rules: Optional[dict] = None) -> jax.Array:
    """with_sharding_constraint via the logical rules (no-op off-mesh)."""
    if mesh is None:
        return x
    spec = spec_for(mesh, tuple(logical), tuple(x.shape), rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
