"""XOR collectives over the zone (data) axis.

Pangolin's parity algebra is XOR end-to-end: building parity is an XOR
reduction of chunk rows, patches are XOR deltas, reconstruction is XOR of
survivors with parity (§3.1, §3.5-3.6).  XOR is associative and commutative
but is not one of XLA's native collective reductions, so the collectives
here compose it from data movement (all-to-all / all-gather / ppermute)
plus local folds — bandwidth-equivalent to their psum counterparts.

All functions run *inside* a shard_map; `axis_name` names the zone axis of
size G.  Operands are uint32 word buffers (bit patterns, never floats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def xor_fold(x: jax.Array, axis: int = 0) -> jax.Array:
    """Local XOR reduction along one axis (no communication)."""
    return lax.reduce(x, jnp.asarray(0, x.dtype), lax.bitwise_xor, (axis,))


def xor_reduce_scatter(row: jax.Array, axis_name: str) -> jax.Array:
    """XOR-reduce rows across the zone; rank i keeps segment i.

    row: (n,) with n divisible by G.  Returns (n // G,): the i-th length-n/G
    segment of the XOR of all G rows, on rank i.  One all-to-all moves each
    rank's G-1 foreign segments (same wire bytes as a ring reduce-scatter);
    the XOR combine is a local fold.
    """
    g = lax.psum(1, axis_name)
    n = row.shape[0]
    assert n % g == 0, (n, g)
    segs = row.reshape(g, n // g)
    # Non-tiled all-to-all swaps the leading positional axis with the mesh
    # axis: afterwards rank i holds segment i of every rank's row.
    gathered = lax.all_to_all(segs, axis_name, split_axis=0, concat_axis=0)
    return xor_fold(gathered, axis=0)


def all_gather_row(seg: jax.Array, axis_name: str) -> jax.Array:
    """Concatenate per-rank segments back into the full row (rank order)."""
    return lax.all_gather(seg, axis_name, axis=0, tiled=True)


def xor_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """XOR of x across the zone, delivered to every rank (any shape).

    Implemented as reduce-scatter + all-gather (the standard bandwidth-
    optimal decomposition); the flat payload is padded up to a multiple of
    G for the scatter and sliced back afterwards.
    """
    g = lax.psum(1, axis_name)
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % g
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    seg = xor_reduce_scatter(flat, axis_name)
    full = all_gather_row(seg, axis_name)
    return full[:n].reshape(shape)


def _split_chunks(seg_words: int, chunks: int) -> int:
    """Largest chunk count <= `chunks` that divides the segment length."""
    c = max(1, min(int(chunks), seg_words))
    while seg_words % c:
        c -= 1
    return c


def syndrome_reduce_scatter(row: jax.Array, r: int, axis_name: str, *,
                            chunks: int = 1) -> jax.Array:
    """All r syndrome reduce-scatters as ONE overlapped collective.

    Returns the (r, n // G) stack: rank i keeps segment i of every
    S_k = XOR_j g^(k·j) · row_j, k = 0..r-1.  Sequencing r separate
    reduce-scatters would serialize r all-to-alls on the same ring; here
    the r weighted rows ride a single batched all-to-all (split over the
    rank axis of the (r, G, seg) stack), so the syndromes share one
    communication launch and the interconnect overlaps their transfers —
    the "independent communication streams" of the ROADMAP follow-up,
    expressed as collective batching.  The k=0 row skips the clmul
    entirely (g^0 = 1), so r=1 degenerates to `xor_reduce_scatter`
    exactly.

    `chunks > 1` splits every rank-segment column-wise into that many
    pieces and runs weight + all-to-all + fold per piece (a static
    unrolled loop, so XLA can overlap piece i+1's clmul with piece i's
    transfer — the commit sweep of an arbitrarily large row pipelines
    compute against the wire).  Chunking slices the *segment* axis, so
    the concatenated pieces are positionally identical to the unchunked
    result; GF weighting is element-wise, so bit-identical too.
    """
    from repro.core import gf          # lazy: core.parity imports this module
    r = int(r)
    assert r >= 1, r
    g = lax.psum(1, axis_name)
    n = row.shape[0]
    assert n % g == 0, (n, g)
    seg = n // g
    c = _split_chunks(seg, chunks)
    if r == 1:
        if c == 1:
            return xor_reduce_scatter(row, axis_name)[None]
        segs = row.reshape(g, seg)
        sc = seg // c
        pieces = []
        for i in range(c):
            part = segs[:, i * sc:(i + 1) * sc]
            gathered = lax.all_to_all(part, axis_name, split_axis=0,
                                      concat_axis=0)
            pieces.append(xor_fold(gathered, axis=0))
        return jnp.concatenate(pieces, axis=-1)[None]
    coeffs = gf.rank_syndrome_coeffs(g, r, axis_name)
    segs = row.reshape(g, seg)
    sc = seg // c
    pieces = []
    for i in range(c):
        part = segs[:, i * sc:(i + 1) * sc]
        weighted = jnp.stack(
            [part] + [gf.mul_const(part, coeffs[k]) for k in range(1, r)])
        gathered = lax.all_to_all(weighted, axis_name, split_axis=1,
                                  concat_axis=1)
        pieces.append(xor_fold(gathered, axis=1))
    return jnp.concatenate(pieces, axis=-1)


def syndrome_apply_delta(synd: jax.Array, sdelta: jax.Array,
                         axis_name: str, *, chunks: int = 1) -> jax.Array:
    """Bulk syndrome delta: synd ^= reduce-scatter of pre-weighted deltas.

    `synd`: (r, seg) stack; `sdelta`: (r, n) pre-weighted delta rows (the
    fused commit sweep emits g^(k·me)·(old^new) directly), so the combine
    is the plain XOR collective — batched over all r syndromes in one
    all-to-all, exactly like `syndrome_reduce_scatter`.  `chunks > 1`
    splits the segments column-wise into that many all-to-alls (static
    unrolled loop) so large-pool transfers pipeline.
    """
    r = synd.shape[0]
    g = lax.psum(1, axis_name)
    n = sdelta.reshape(r, -1).shape[-1]
    seg = n // g
    c = _split_chunks(seg, chunks)
    if r == 1 and c == 1:
        return synd ^ xor_reduce_scatter(sdelta.reshape(-1), axis_name)[None]
    segs = sdelta.reshape(r, g, seg)
    sc = seg // c
    pieces = []
    for i in range(c):
        part = segs[:, :, i * sc:(i + 1) * sc]
        gathered = lax.all_to_all(part, axis_name, split_axis=1,
                                  concat_axis=1)
        pieces.append(xor_fold(gathered, axis=1))
    return synd ^ jnp.concatenate(pieces, axis=-1)


def meta_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Replicate small per-rank metadata across the zone (window meta).

    Inside a shard_map: every rank receives the stacked (G, *x.shape)
    table — out[i] is rank i's value, identical on every rank.  This is
    the *secondary* all-gather the deferred engine's window-meta mirror
    rides: a few hundred bytes per commit (dirty mask + digests +
    pending count), dispatched asynchronously so the commit path never
    blocks on the host, and pod-replicated so the survivors of a
    mid-window rank loss still hold the lost rank's copy (a rank-local
    `jnp.copy` mirror dies with its rank).
    """
    return lax.all_gather(x, axis_name, axis=0, tiled=False)


def make_meta_mirror(mesh):
    """Build the async window-meta replication program (host-callable).

    A jitted identity whose outputs are forced to the fully-replicated
    sharding: XLA lowers the resharding to the pod all-gather, the call
    dispatches without any host synchronization, and the result is a
    fresh replicated buffer set — donation of the live window state can
    never invalidate it, and every device holds every rank's copy.
    `None` leaves (a bulk engine's absent dirty mask) pass through.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    repl = NamedSharding(mesh, PartitionSpec())
    return jax.jit(lambda tree: tree, out_shardings=repl)


def xor_tree_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Recursive-doubling XOR all-reduce (power-of-two zones).

    log2(G) butterfly rounds of pairwise exchange; each round XORs the
    partner's buffer in.  Latency-optimal for small payloads (parity
    patches of a few dirty pages), where the reduce-scatter pipeline of
    `xor_all_reduce` is all fixed cost.
    """
    g = lax.psum(1, axis_name)
    assert g & (g - 1) == 0, f"tree reduce needs power-of-two zone, got {g}"
    out = x
    d = 1
    while d < g:
        perm = [(i, i ^ d) for i in range(g)]
        out = out ^ lax.ppermute(out, axis_name, perm)
        d *= 2
    return out
