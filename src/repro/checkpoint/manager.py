"""Async disk checkpointing — the backstop tier below in-HBM parity.

Tier-0 (this paper's contribution) repairs rank loss / scribbles from
parity in seconds.  Tier-1 (this module) covers correlated failures that
defeat parity (>1 row per page column): versioned, digest-verified,
atomically-renamed checkpoints written by a background thread so the train
loop never blocks on disk.

Format: <dir>/step_<n>/{manifest.json, arrays.npz}.  The manifest carries
a Fletcher digest per leaf, verified on restore (the same detection class
the paper uses for its pool).  Restore re-shards onto any mesh whose
divisibility constraints the state satisfies — the elastic-rescale path
(dist/elastic.py) reuses it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def _digest(arr: np.ndarray) -> list:
    w = np.frombuffer(arr.tobytes(), dtype=np.uint32) if arr.nbytes % 4 == 0 \
        else np.frombuffer(arr.tobytes() + b"\0" * (4 - arr.nbytes % 4),
                           dtype=np.uint32)
    n = np.uint32(len(w))
    a = np.uint32(w.sum(dtype=np.uint64) & 0xFFFFFFFF)
    weights = (n - np.arange(len(w), dtype=np.uint64)) & 0xFFFFFFFF
    b = np.uint32((w.astype(np.uint64) * weights).sum(dtype=np.uint64)
                  & 0xFFFFFFFF)
    return [int(a), int(b)]


def _flatten_with_paths(tree: PyTree) -> dict:
    out = {}
    for path, leaf in jax.tree.leaves_with_path(tree):
        out[jax.tree_util.keystr(path)] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, mesh=None, state_specs: PyTree = None,
                 keep: int = 3):
        self.directory = directory
        self.mesh = mesh
        self.state_specs = state_specs
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: PyTree, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        extra_host = jax.tree.map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x,
            extra or {})

        def _write():
            try:
                tmp = os.path.join(self.directory, f".tmp_step_{step}")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                flat = _flatten_with_paths(host)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{k: v for k, v in flat.items()})
                manifest = {
                    "step": step,
                    "time": time.time(),
                    "digests": {k: _digest(v) for k, v in flat.items()},
                    "extra": _jsonable(extra_host),
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)   # atomic publish
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {e}") from e

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def list_steps(self) -> list:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def restore(self, step: int, template: PyTree = None,
                mesh=None, state_specs: PyTree = None) -> tuple:
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        npz = np.load(os.path.join(d, "arrays.npz"))
        for k in npz.files:
            if _digest(npz[k]) != manifest["digests"][k]:
                raise RuntimeError(f"checkpoint digest mismatch for {k}")
        if template is not None:
            want = {k: tuple(v.shape)
                    for k, v in _flatten_with_paths(template).items()}
            for k in npz.files:
                if k in want and tuple(npz[k].shape) != want[k]:
                    raise ValueError(
                        f"checkpoint step {step} leaf {k} has shape "
                        f"{npz[k].shape}, expected {want[k]} — restoring a "
                        "checkpoint from a different model configuration?")
        mesh = mesh or self.mesh
        state_specs = state_specs if state_specs is not None \
            else self.state_specs
        # rebuild tree structure from key paths using the spec tree
        flat_specs = _flatten_with_paths(state_specs) \
            if state_specs is not None else None
        leaves, treedef = (jax.tree.flatten(state_specs,
                                            is_leaf=_is_spec)
                           if state_specs is not None else (None, None))
        arrays = {}
        for k in npz.files:
            arr = npz[k]
            if mesh is not None and flat_specs is not None and k in flat_specs:
                arrays[k] = jax.device_put(
                    arr, NamedSharding(mesh, flat_specs[k]))
            else:
                arrays[k] = jnp.asarray(arr)
        if treedef is not None:
            keys = list(_flatten_with_paths(state_specs).keys())
            state = jax.tree.unflatten(treedef, [arrays[k] for k in keys])
        else:
            state = arrays
        return state, manifest.get("extra", {})

    def restore_latest(self) -> tuple:
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        step = steps[-1]
        state, extra = self.restore(step)
        return step, state, extra


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _jsonable(x):
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return {"__ndarray__": x.tolist(), "dtype": str(x.dtype),
                "shape": list(x.shape)}
    if hasattr(x, "tree_flatten"):  # RedoLog etc.
        children, _ = x.tree_flatten()
        return {"__pytree__": type(x).__name__,
                "children": [_jsonable(np.asarray(c)) for c in children]}
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x
