"""Declarative parameter definitions.

Each module declares its parameters as `ParamDef`s (shape, dtype, logical
axes, initializer).  From one definition tree we derive:

  * initialized parameter pytrees (`init_params`),
  * abstract ShapeDtypeStructs for the dry-run (`abstract_params`) — no
    allocation,
  * PartitionSpecs via the logical-axis rules (`spec_tree`).

Layer stacks are declared once and `stacked` over a leading "layers" axis so
the model scans over groups (one compiled layer body regardless of depth).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    dtype: Any
    logical: tuple                      # logical axis names, len == ndim
    init: str = "normal"                # normal | zeros | ones | scaled
    scale: float = 1.0

    def with_stack(self, n: int) -> "ParamDef":
        return ParamDef(shape=(n,) + self.shape, dtype=self.dtype,
                        logical=("layers",) + self.logical, init=self.init,
                        scale=self.scale)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stacked(defs: PyTree, n: int) -> PyTree:
    """Add a leading layer axis of size n to every ParamDef in the tree."""
    return jax.tree.map(lambda d: d.with_stack(n), defs, is_leaf=_is_def)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)), defs,
        is_leaf=_is_def)


def _init_one(d: ParamDef, key) -> jax.Array:
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "normal":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
        std = d.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dt)
    if d.init == "scaled":
        return (jax.random.normal(key, d.shape, jnp.float32)
                * d.scale).astype(dt)
    raise ValueError(d.init)


def init_params(defs: PyTree, key) -> PyTree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    return jax.tree.unflatten(
        treedef, [_init_one(d, k) for d, k in zip(leaves, keys)])


def spec_tree(defs: PyTree, mesh, rules: Optional[dict] = None) -> PyTree:
    return jax.tree.map(
        lambda d: shd.spec_for(mesh, d.logical, d.shape, rules), defs,
        is_leaf=_is_def)


def count(defs: PyTree) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=_is_def))
