"""GQA attention: chunked (online-softmax) training path, KV-cache decode.

The training/prefill path is a blockwise "flash"-style attention in pure
JAX: a scan over KV chunks with an online-softmax carry keeps the score
matrix working set at (q_chunk x kv_chunk) instead of S^2 — the memory
roofline term for 32k prefill depends on it.  Decode attends one query
against a linear or ring (sliding-window) cache.

GQA: queries are grouped as (B, S, K, g, hd) with g = H // K so scores are
computed against un-broadcast KV heads.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamDef

NEG_INF = -1e30


def attn_defs(cfg, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    defs = {
        "wq": ParamDef((d, H, hd), cfg.param_dtype,
                       ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, K, hd), cfg.param_dtype,
                       ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, K, hd), cfg.param_dtype,
                       ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), cfg.param_dtype,
                       ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((H, hd), cfg.param_dtype,
                              ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((K, hd), cfg.param_dtype,
                              ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((K, hd), cfg.param_dtype,
                              ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["qnorm"] = ParamDef((hd,), cfg.param_dtype, ("head_dim",),
                                 init="ones")
        defs["knorm"] = ParamDef((hd,), cfg.param_dtype, ("head_dim",),
                                 init="ones")
    return defs


def _headnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def project_q(p: dict, x: jax.Array, cfg, positions, *, use_rope=True,
              mesh=None):
    dt = L.cdt(cfg)
    wq = L.gather_fsdp(p["wq"].astype(dt), mesh,
                       (None, "heads", "head_dim"))
    q = jnp.einsum("...sd,dhk->...shk", x.astype(dt), wq,
                   preferred_element_type=jnp.float32).astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if "qnorm" in p:
        q = _headnorm(q, p["qnorm"])
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
    return q


def project_kv(p: dict, x: jax.Array, cfg, positions, *, use_rope=True,
               mesh=None):
    dt = L.cdt(cfg)
    wk = L.gather_fsdp(p["wk"].astype(dt), mesh,
                       (None, "kv_heads", "head_dim"))
    wv = L.gather_fsdp(p["wv"].astype(dt), mesh,
                       (None, "kv_heads", "head_dim"))
    k = jnp.einsum("...sd,dhk->...shk", x.astype(dt), wk,
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("...sd,dhk->...shk", x.astype(dt), wv,
                   preferred_element_type=jnp.float32).astype(dt)
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "knorm" in p:
        k = _headnorm(k, p["knorm"])
    if use_rope:
        k = L.rope(k, positions, cfg.rope_theta)
    return k, v


def apply_out(p: dict, attn: jax.Array, cfg, mesh=None) -> jax.Array:
    dt = L.cdt(cfg)
    wo = L.gather_fsdp(p["wo"].astype(dt), mesh,
                       ("heads", "head_dim", None))
    return jnp.einsum("...shk,hkd->...sd", attn.astype(dt), wo,
                      preferred_element_type=jnp.float32).astype(dt)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------
#
# The forward is a flash-style blockwise scan: the (qc x kc) score tile is
# the only quadratic object and it lives in registers/VMEM, never HBM.  The
# BACKWARD is a custom VJP that recomputes score tiles blockwise from
# (q, k, v, lse) — without it, jax.grad of the nested scan stacks every
# score tile as a residual ([nq, nk, B, K, g, qc, kc] f32: the full S^2
# matrix re-materialized, ~15 GB/layer for 4k tokens), which defeats the
# chunking entirely.  See EXPERIMENTS.md §Perf iteration 1.
#
# Block positions derive from the scan induction variable (not from
# precomputed arange arrays), so the causal/window masks are computed
# per-tile inside the loop; constant position inputs invite XLA's
# loop-invariant code motion to hoist ALL tiles' masks into a carried
# S^2-bool buffer.

def _pick_chunk(s: int, target: int) -> int:
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _tile_specs(mesh, K: int, g: int, qc: int, kc: int, T: int = 1 << 30):
    """Sharding hints for attention tiles on `mesh` (None = no hint).

    GQA head counts rarely divide a 16-wide model axis, and the default
    rule fallback then REPLICATES the whole attention computation across
    it — a 16x waste of FLOPs and HBM traffic (EXPERIMENTS.md §Perf
    iteration 2).  Preference order:
      1. shard KV heads            (K % tp == 0: moonshot, seamless),
      2. shard q-head groups       (g % tp == 0: glm4's g=16),
      3. shard the q-tile rows     (sequence/context parallelism — always
         divides since tiles are hardware-aligned).
    Returns (q_axes, kv_axes, out_axes) logical-axis tuples for the
    (nq, B, qc, K, g, hd) / (nk, B, kc, K, hd) / (nq, B, qc, H, hd)
    stacked tile layouts.
    """
    if mesh is None:
        return None, None, None
    tp = dict(getattr(mesh, "shape", {})).get("model", 1)
    if tp <= 1:
        return None, None, None
    if K % tp == 0:
        return ((None, "batch", None, "kv_heads", None, None),
                (None, "batch", None, "kv_heads", None),
                (None, "batch", None, "kv_heads", None))
    if (K * g) % tp == 0:
        # H divides the model axis: GSPMD propagates the projection weights'
        # head sharding into the tiles as a (K, g)-composite split on its
        # own.  Hints here only fight it — a forced g-shard layout was tried
        # and REFUTED (glm4 prefill: collective 3.3 s -> 24.1 s from per-
        # layer resharding), and a forced seq-shard also lost (memory 67 s
        # -> 120 s).  See EXPERIMENTS.md §Perf iteration 2.
        return None, None, None
    if qc % tp == 0:
        # Context parallelism.  Costs: attention weight grads become
        # partial sums over the model axis (all-reduced per microbatch x
        # layer).  A "gate off below 16k context" variant was tried and
        # REFUTED: replicated attention's memory term is far worse even at
        # 4k (qwen2 train 2.95 s -> 18.7 s; llama4 train 65 s -> 165 s) —
        # §Perf iteration 5.
        return ((None, "batch", "seq_shard", None, None, None),
                (None, "batch", None, None, None),
                (None, "batch", "seq_shard", None, None))
    return None, None, None


def _hint(x, mesh, axes):
    if mesh is None or axes is None:
        return x
    from repro.dist import sharding as shd
    return shd.constrain(x, mesh, axes)


def _tile_mask(causal: bool, window: Optional[int], qp, kp, qc: int, kc: int):
    """(qc, kc) bool mask for a tile at query offset qp, key offset kp."""
    qpos = qp + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kpos = kp + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = jnp.ones((qc, kc), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    return mask


def _attend_fwd_impl(cfg, q, k, v):
    """Returns (out, lse).  out: (B,S,H,hd); lse: (B,K,g,S) f32."""
    causal, window, chunk, mesh = cfg
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)
    qc = _pick_chunk(S, chunk)
    kc = _pick_chunk(T, chunk)
    nq, nk = S // qc, T // kc
    q_axes, kv_axes, out_axes = _tile_specs(mesh, K, g, qc, kc, T)

    qr = jnp.moveaxis(q.reshape(B, nq, qc, K, g, hd), 1, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, K, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, K, hd), 1, 0)
    qr = _hint(qr, mesh, q_axes)
    kr = _hint(kr, mesh, kv_axes)
    vr = _hint(vr, mesh, kv_axes)

    def q_block(args):
        qi, i = args                      # (B, qc, K, g, hd), scalar block id
        m0 = jnp.full((B, K, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, g, qc), jnp.float32)
        a0 = jnp.zeros((B, K, g, qc, hd), jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            s = jnp.einsum("bqkgh,bckh->bkgqc", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(causal, window, i * qc, j * kc, qc, kc)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(qi.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(
            body, (m0, l0, a0), (kr, vr, jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)                       # (B, K, g, qc)
        out = jnp.moveaxis(out, 3, 1).reshape(B, qc, H, hd)
        return out.astype(q.dtype), lse

    outs, lses = lax.map(q_block, (qr, jnp.arange(nq)))
    outs = _hint(outs, mesh, out_axes)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    # lses: (nq, B, K, g, qc) -> (B, K, g, S)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, K, g, S)
    return out, lse


def _attend_bwd_impl(cfg, res, dout):
    """Flash backward: recompute score tiles; only lse was saved."""
    causal, window, chunk, mesh = cfg
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)
    qc = _pick_chunk(S, chunk)
    kc = _pick_chunk(T, chunk)
    nq, nk = S // qc, T // kc
    q_axes, kv_axes, _ = _tile_specs(mesh, K, g, qc, kc, T)

    qr = jnp.moveaxis(q.reshape(B, nq, qc, K, g, hd), 1, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, K, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, K, hd), 1, 0)
    dor = jnp.moveaxis(dout.reshape(B, nq, qc, K, g, hd), 1, 0)
    qr = _hint(qr, mesh, q_axes)
    kr = _hint(kr, mesh, kv_axes)
    vr = _hint(vr, mesh, kv_axes)
    dor = _hint(dor, mesh, q_axes)
    lser = jnp.moveaxis(lse.reshape(B, K, g, nq, qc), 3, 0)  # (nq,B,K,g,qc)
    # D = rowsum(dout * out): (B, S, H) -> (nq, B, K, g, qc)
    d_row = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)
    d_row = jnp.moveaxis(
        d_row.reshape(B, nq, qc, K, g), 1, 0).transpose(0, 1, 3, 4, 2)

    def q_iter(carry, inp):
        dk_acc, dv_acc = carry            # (nk, B, kc, K, hd) f32
        qi, doi, lsei, di, i = inp

        def kv_iter(dq_i, inp2):
            kj, vj, j = inp2
            s = jnp.einsum("bqkgh,bckh->bkgqc", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(causal, window, i * qc, j * kc, qc, kc)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])            # (B,K,g,qc,kc) f32
            dp = jnp.einsum("bqkgh,bckh->bkgqc", doi, vj,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - di[..., None]) * scale       # (B,K,g,qc,kc)
            dq_t = jnp.einsum("bkgqc,bckh->bqkgh", ds.astype(kj.dtype), kj,
                              preferred_element_type=jnp.float32)
            dk_t = jnp.einsum("bkgqc,bqkgh->bckh", ds.astype(qi.dtype), qi,
                              preferred_element_type=jnp.float32)
            dv_t = jnp.einsum("bkgqc,bqkgh->bckh", p.astype(doi.dtype), doi,
                              preferred_element_type=jnp.float32)
            return dq_i + dq_t, (dk_t, dv_t)

        dq0 = jnp.zeros((B, qc, K, g, hd), jnp.float32)
        dq_i, (dks, dvs) = lax.scan(kv_iter, dq0, (kr, vr, jnp.arange(nk)))
        return (dk_acc + dks, dv_acc + dvs), dq_i

    zk = jnp.zeros((nk, B, kc, K, hd), jnp.float32)
    (dk_f, dv_f), dqs = lax.scan(
        q_iter, (zk, zk), (qr, dor, lser, d_row, jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, H, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_f, 0, 1).reshape(B, T, K, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv_f, 0, 1).reshape(B, T, K, hd).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attend_cw(cfg, q, k, v):
    out, _ = _attend_fwd_impl(cfg, q, k, v)
    return out


def _attend_cw_fwd(cfg, q, k, v):
    out, lse = _attend_fwd_impl(cfg, q, k, v)
    return out, (q, k, v, out, lse)


_attend_cw.defvjp(_attend_cw_fwd, _attend_bwd_impl)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool, window: Optional[int] = None,
           chunk: int = 256, mesh=None) -> jax.Array:
    """Blockwise attention.  q: (B,S,H,hd); k,v: (B,T,K,hd) -> (B,S,H,hd).

    Query position i attends key position j under `causal` (j <= i) and
    `window` (i - j < window); positions are block-index-derived (both
    sequences start at position 0).  `mesh` enables tile sharding hints
    (see `_tile_specs`).
    """
    return _attend_cw((causal, window, int(chunk), mesh), q, k, v)


# ---------------------------------------------------------------------------
# decode (single query against a cache)
# ---------------------------------------------------------------------------

def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  slot_positions: jax.Array, pos: jax.Array, *,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B,1,H,hd); caches: (B,T,K,hd); slot_positions: (T,) true position
    stored in each slot (-1 = empty).  Returns (B,1,H,hd)."""
    B, _, H, hd = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, K, g, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (slot_positions >= 0) & (slot_positions <= pos)
    if window is not None:
        valid &= slot_positions > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache: jax.Array, v_cache: jax.Array,
                 slot_positions: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pos: jax.Array, *,
                 window: Optional[int] = None):
    """Insert one step's k/v at the (possibly ring-buffer) slot for `pos`."""
    T = k_cache.shape[1]
    slot = (pos % T) if window is not None else pos
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)
    slot_positions = lax.dynamic_update_slice_in_dim(
        slot_positions, pos[None].astype(slot_positions.dtype), slot, axis=0)
    return k_cache, v_cache, slot_positions
