"""Public model API: param counting, step functions, dry-run input specs.

`input_specs(cfg, workload, mesh)` returns ShapeDtypeStructs (+ shardings)
for every model input of a workload cell — the dry-run lowers against these
with zero allocation.  `make_train_step` / `make_decode_step` build the
jittable step functions used by the trainer, the server, and the dry-run.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, Workload
from repro.dist import sharding as shd
from repro.models import params as prm
from repro.models.transformer import Model, build_model
from repro.optim import Optimizer, clip_by_global_norm

PyTree = Any


# ---------------------------------------------------------------------------
# parameter counting (for the 6ND roofline term)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    model = build_model(cfg)
    defs = model.param_defs()
    total = 0
    for path, d in jax.tree.leaves_with_path(
            defs, is_leaf=lambda x: isinstance(x, prm.ParamDef)):
        n = int(np.prod(d.shape))
        if active_only and cfg.moe is not None and "experts" in d.logical:
            # only top_k of num_experts participate per token
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# input specs per workload (dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_abstract(cfg: ModelConfig, wl: Workload) -> dict:
    """Abstract batch for train/prefill workloads."""
    B, S = wl.global_batch, wl.seq_len
    batch = {"tokens": _sds((B, S - cfg.mm_positions), jnp.int32)}
    if cfg.mm_positions:
        batch["mm_embeds"] = _sds((B, cfg.mm_positions, cfg.d_model),
                                  cfg.compute_dtype)
    if cfg.enc_layers:
        batch["src_embeds"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
    return batch


def batch_specs(cfg: ModelConfig, mesh, global_batch: int = 1 << 30) -> dict:
    rules = cfg.logical_overrides
    B = global_batch
    specs = {"tokens": shd.spec_for(mesh, ("batch", None), (B, 1), rules)}
    if cfg.mm_positions:
        specs["mm_embeds"] = shd.spec_for(
            mesh, ("batch", None, None), (B, 1, 1), rules)
    if cfg.enc_layers:
        specs["src_embeds"] = shd.spec_for(
            mesh, ("batch", None, None), (B, 1, 1), rules)
    return specs


def decode_abstract(cfg: ModelConfig, wl: Workload, model: Model) -> dict:
    """Abstract (token, cache, pos) for decode workloads."""
    B, T = wl.global_batch, wl.seq_len
    cache = jax.eval_shape(lambda: model._cache_defs(B, T))
    return {"token": _sds((B,), jnp.int32), "cache": cache,
            "pos": _sds((), jnp.int32)}


def decode_specs(cfg: ModelConfig, wl: Workload, model: Model, mesh) -> dict:
    return {
        "token": shd.spec_for(mesh, ("batch",), (wl.global_batch,),
                              cfg.logical_overrides),
        "cache": model.cache_specs(wl.global_batch, wl.seq_len, mesh),
        "pos": P(),
    }


# ---------------------------------------------------------------------------
# train / serve step builders
# ---------------------------------------------------------------------------

def init_train_state(model: Model, optimizer: Optimizer, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model, optimizer: Optimizer) -> dict:
    params = prm.abstract_params(model.param_defs())
    return jax.eval_shape(
        lambda p: {"params": p, "opt": optimizer.init(p),
                   "step": jnp.zeros((), jnp.int32)}, params)


def train_state_specs(model: Model, optimizer: Optimizer, mesh) -> dict:
    pspecs = model.param_specs(mesh)
    return {"params": pspecs, "opt": optimizer.state_specs(pspecs),
            "step": P()}


def make_train_step(model: Model, optimizer: Optimizer, train_cfg,
                    donate: bool = False):
    """Returns train_step(state, batch) -> (new_state, metrics).

    Supports microbatch gradient accumulation and per-example loss masks
    (straggler mitigation drops slow replicas' examples via the mask).
    """
    nmb = train_cfg.microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        if "loss_mask" in batch:
            # per-example weighting handled inside model.loss would need
            # plumbing; re-weight the scalar instead for replica drops where
            # the mask is constant within a replica's examples
            w = jnp.mean(batch["loss_mask"].astype(jnp.float32))
            loss = loss * w / jnp.maximum(w, 1e-9)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if nmb > 1:
            def mb_body(carry, mb):
                gacc, lacc = carry
                loss, _, grads = single(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            mb_batches = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(mb_body, (zeros, 0.0), mb_batches)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss / nmb
            metrics = {}
        else:
            loss, metrics, grads = single(params, batch)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        new_params, new_opt = optimizer.update(grads, state["opt"], params,
                                               state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics or {}, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_forward(model: Model):
    """Full-sequence forward: batch -> (B, S, V) logits (eval/scoring)."""
    def forward(params, batch):
        logits, _ = model.forward(params, batch)
        return logits
    return forward


def make_prefill(model: Model):
    """Serving prefill: batch -> next-token logits (B, V).

    Slices the hidden state to the last position BEFORE the unembedding so
    the (B, S, vocab) logits tensor never materializes — for seamless
    (vocab 256k) that tensor alone is 33.5 GiB/device at 32k context.
    """
    def prefill(params, batch):
        x, _ = model.hidden(params, batch)
        from repro.models import layers as L
        logits = L.apply_unembed(params["embed"], x[:, -1:, :], model.cfg)
        return logits[:, 0]
    return prefill


def make_decode_step(model: Model, sample: str = "greedy"):
    """serve_step: one new token against a full KV cache (decode cells)."""
    def decode_step(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos)
        if sample == "greedy":
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return next_tok, logits, cache
    return decode_step
