"""Model assembly: decoder-only LMs and encoder-decoder over the block registry.

Layers are grouped by the config's block pattern and *scanned*: parameters
of each pattern position are stacked over a leading "layers" axis, so the
compiled program contains one group body regardless of depth (48-layer
llama4 compiles the same body as 24-layer seamless).  Remat wraps the group
body.  Any `n_layers % len(pattern)` tail runs unrolled.

Entry points (used by runtime / launch / dryrun):
    init(key)                      -> params
    forward(params, batch)         -> logits          (train fwd & prefill)
    loss(params, batch)            -> (scalar, metrics)
    init_cache(batch, max_len)     -> cache pytree
    decode_step(params, tok, cache, pos [, cross]) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import params as prm

PyTree = Any


class Model:
    """Decoder-only LM (also hosts the hybrid/ssm families)."""

    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.pattern = cfg.pattern
        self.n_groups = cfg.n_groups
        self.tail = cfg.tail_pattern

    # -- parameter definitions -------------------------------------------------

    def param_defs(self) -> PyTree:
        cfg = self.cfg
        group = {f"b{j}_{t}": B.block_defs(cfg, t)
                 for j, t in enumerate(self.pattern)}
        defs = {
            "embed": L.embed_defs(cfg),
            "groups": prm.stacked(group, self.n_groups),
            "final_norm": L.rmsnorm_defs(cfg.d_model, cfg),
        }
        for i, t in enumerate(self.tail):
            defs[f"tail{i}_{t}"] = B.block_defs(cfg, t)
        return defs

    def abstract_params(self) -> PyTree:
        return prm.abstract_params(self.param_defs())

    def param_specs(self, mesh=None) -> PyTree:
        return prm.spec_tree(self.param_defs(), mesh or self.mesh,
                             self.cfg.logical_overrides)

    def init(self, key) -> PyTree:
        return prm.init_params(self.param_defs(), key)

    # -- embedding of (tokens, optional multimodal stub embeds) ---------------

    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = L.apply_embed(params["embed"], batch["tokens"], cfg)
        if cfg.mm_positions:
            mm = batch["mm_embeds"].astype(x.dtype)
            x = jnp.concatenate([mm, x], axis=1)
        return x

    # -- full-sequence forward (training fwd / serving prefill) ----------------

    def hidden(self, params, batch) -> tuple:
        """Backbone output before unembedding: (x (B,S,D), aux_total)."""
        cfg, mesh = self.cfg, self.mesh
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)
        if mesh is not None:
            x = shd.constrain(x, mesh, ("batch", None, None))

        def group_body(x, gp):
            aux_total = jnp.zeros((), jnp.float32)
            for j, t in enumerate(self.pattern):
                x, aux = B.apply_train(gp[f"b{j}_{t}"], t, x, cfg,
                                       positions=positions, mesh=mesh)
                for k in ("load_balance", "router_z"):
                    if k in aux:
                        aux_total = aux_total + aux[k]
            if mesh is not None:
                x = shd.constrain(x, mesh, ("batch", None, None))
            return x, aux_total

        body = jax.checkpoint(group_body) if self.n_groups > 1 else group_body
        x, auxs = lax.scan(body, x, params["groups"])
        aux_total = jnp.sum(auxs)
        for i, t in enumerate(self.tail):
            x, aux = B.apply_train(params[f"tail{i}_{t}"], t, x, cfg,
                                   positions=positions, mesh=mesh)
            for k in ("load_balance", "router_z"):
                if k in aux:
                    aux_total = aux_total + aux[k]
        x = L.apply_rmsnorm(params["final_norm"], x)
        return x, aux_total

    def forward(self, params, batch) -> tuple:
        cfg, mesh = self.cfg, self.mesh
        x, aux_total = self.hidden(params, batch)
        logits = L.apply_unembed(params["embed"], x, cfg)
        if mesh is not None:
            logits = shd.constrain(logits, mesh, ("batch", None, "vocab"))
        return logits, aux_total

    def _chunked_ce(self, params, x, targets, valid) -> tuple:
        """CE over seq chunks so full-vocab logits never materialize.

        x: (B, S, D) hidden; targets: (B, S) ids; valid: (B, S) bool.
        """
        cfg, mesh = self.cfg, self.mesh
        B_, S, D = x.shape
        c = min(512, S)
        while S % c:
            c -= 1
        nc = S // c
        xs = (x.reshape(B_, nc, c, D).swapaxes(0, 1),
              targets.reshape(B_, nc, c).swapaxes(0, 1),
              valid.reshape(B_, nc, c).swapaxes(0, 1))

        def body(carry, inp):
            ce_sum, z_sum, n = carry
            xc, tc, vc = inp
            lg = L.apply_unembed(params["embed"], xc, cfg).astype(jnp.float32)
            if mesh is not None:
                lg = shd.constrain(lg, mesh, ("batch", None, "vocab"))
            lse = jax.scipy.special.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
            vf = vc.astype(jnp.float32)
            ce_sum = ce_sum + jnp.sum((lse - ll) * vf)
            z_sum = z_sum + jnp.sum((lse ** 2) * vf)
            return (ce_sum, z_sum, n + jnp.sum(vf)), None

        (ce_sum, z_sum, n), _ = lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), xs)
        n = jnp.maximum(n, 1.0)
        return ce_sum / n, z_sum / n

    def loss(self, params, batch) -> tuple:
        cfg = self.cfg
        x, aux = self.hidden(params, batch)
        # next-token CE on token positions (skip the mm stub prefix)
        x = x[:, cfg.mm_positions:, :]
        tokens = batch["tokens"]
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        valid = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], dtype=bool),
             jnp.zeros_like(tokens[:, :1], dtype=bool)], axis=1)
        ce, zterm = self._chunked_ce(params, x, targets, valid)
        z_loss = 1e-4 * zterm
        moe_coef = 0.01 if cfg.moe is not None else 0.0
        total = ce + z_loss + moe_coef * aux
        return total, {"ce": ce, "z_loss": z_loss, "aux": aux}

    # -- decode -----------------------------------------------------------------

    def _cache_defs(self, batch: int, max_len: int):
        cfg = self.cfg
        group_cache = {f"b{j}_{t}": B.init_cache(cfg, t, batch, max_len)
                       for j, t in enumerate(self.pattern)}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_groups,) + x.shape),
            group_cache)
        tail = {f"tail{i}_{t}": B.init_cache(cfg, t, batch, max_len)
                for i, t in enumerate(self.tail)}
        return {"groups": stacked, **tail}

    def init_cache(self, batch: int, max_len: int) -> PyTree:
        return self._cache_defs(batch, max_len)

    def cache_specs(self, batch: int, max_len: int, mesh=None) -> PyTree:
        cfg = self.cfg
        mesh = mesh or self.mesh
        rules = cfg.logical_overrides
        tp = dict(getattr(mesh, "shape", {})).get("model", 1)

        def spec_of(btype, leafname, arr, stacked):
            axes = B.cache_logical_axes(cfg, btype, tp)[leafname]
            if stacked:
                axes = ("layers",) + tuple(axes)
            return shd.spec_for(mesh, axes, arr.shape, rules)

        cache = jax.eval_shape(lambda: self._cache_defs(batch, max_len))
        specs = {}
        for key, sub in cache.items():
            if key == "groups":
                specs["groups"] = {
                    bk: {ln: spec_of(bk.split("_", 1)[1], ln, arr, True)
                         for ln, arr in leaves.items()}
                    for bk, leaves in sub.items()}
            else:
                bt = key.split("_", 1)[1]
                specs[key] = {ln: spec_of(bt, ln, arr, False)
                              for ln, arr in sub.items()}
        return specs

    def decode_step(self, params, token, cache, pos):
        """token: (B,) int32; pos: scalar int32.  Returns (logits, cache)."""
        cfg, mesh = self.cfg, self.mesh
        x = L.apply_embed(params["embed"], token[:, None], cfg)

        def group_body(x, inp):
            gp, gc = inp
            new_gc = {}
            for j, t in enumerate(self.pattern):
                key = f"b{j}_{t}"
                x, new_gc[key] = B.apply_decode(gp[key], t, x, gc[key],
                                                pos, cfg)
            return x, new_gc

        x, new_group_caches = lax.scan(
            group_body, x, (params["groups"], cache["groups"]))
        new_cache = {"groups": new_group_caches}
        for i, t in enumerate(self.tail):
            key = f"tail{i}_{t}"
            x, new_cache[key] = B.apply_decode(params[key], t, x,
                                               cache[key], pos, cfg)
        x = L.apply_rmsnorm(params["final_norm"], x)
        logits = L.apply_unembed(params["embed"], x, cfg)[:, 0]
        if mesh is not None:
            logits = shd.constrain(logits, mesh, ("batch", "vocab"))
        return logits, new_cache


class EncDecModel(Model):
    """Encoder-decoder (seamless-m4t backbone): stub-embedded source ->
    bidirectional encoder; token target -> causal decoder w/ cross-attn."""

    def __init__(self, cfg: ModelConfig, mesh=None):
        super().__init__(cfg, mesh)
        self.enc_pattern = ("enc",)
        self.n_enc_groups = cfg.enc_layers
        self.pattern = ("dec_x",)
        self.n_groups = cfg.n_layers
        self.tail = ()

    def param_defs(self) -> PyTree:
        cfg = self.cfg
        enc_group = {"b0_enc": B.block_defs(cfg, "enc")}
        dec_group = {"b0_dec_x": B.block_defs(cfg, "dec_x")}
        return {
            "embed": L.embed_defs(cfg),
            "enc_groups": prm.stacked(enc_group, self.n_enc_groups),
            "enc_norm": L.rmsnorm_defs(cfg.d_model, cfg),
            "groups": prm.stacked(dec_group, self.n_groups),
            "final_norm": L.rmsnorm_defs(cfg.d_model, cfg),
        }

    def encode(self, params, src_embeds) -> jax.Array:
        cfg, mesh = self.cfg, self.mesh
        x = src_embeds.astype(jnp.dtype(cfg.compute_dtype))
        positions = jnp.arange(x.shape[1])

        def body(x, gp):
            x, _ = B.apply_train(gp["b0_enc"], "enc", x, cfg,
                                 positions=positions, mesh=mesh,
                                 causal=False)
            if mesh is not None:
                x = shd.constrain(x, mesh, ("batch", None, None))
            return x, jnp.zeros((), jnp.float32)

        body = jax.checkpoint(body) if self.n_enc_groups > 1 else body
        x, _ = lax.scan(body, x, params["enc_groups"])
        return L.apply_rmsnorm(params["enc_norm"], x)

    def hidden(self, params, batch) -> tuple:
        cfg, mesh = self.cfg, self.mesh
        enc_out = self.encode(params, batch["src_embeds"])
        x = L.apply_embed(params["embed"], batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])

        def body(x, gp):
            x, _ = B.apply_train(gp["b0_dec_x"], "dec_x", x, cfg,
                                 positions=positions, mesh=mesh,
                                 enc_out=enc_out)
            if mesh is not None:
                x = shd.constrain(x, mesh, ("batch", None, None))
            return x, jnp.zeros((), jnp.float32)

        body = jax.checkpoint(body) if self.n_groups > 1 else body
        x, _ = lax.scan(body, x, params["groups"])
        x = L.apply_rmsnorm(params["final_norm"], x)
        return x, jnp.zeros((), jnp.float32)

    def forward(self, params, batch) -> tuple:
        x, aux = self.hidden(params, batch)
        logits = L.apply_unembed(params["embed"], x, self.cfg)
        return logits, aux

    def loss(self, params, batch) -> tuple:
        x, aux = self.hidden(params, batch)
        tokens = batch["tokens"]
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        valid = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], dtype=bool),
             jnp.zeros_like(tokens[:, :1], dtype=bool)], axis=1)
        ce, zterm = self._chunked_ce(params, x, targets, valid)
        z_loss = 1e-4 * zterm
        return ce + z_loss, {"ce": ce, "z_loss": z_loss, "aux": aux}

    def _cache_defs(self, batch: int, max_len: int):
        cfg = self.cfg
        self_cache = {"b0_dec_x": B.init_cache(cfg, "dec_x", batch, max_len)}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_groups,) + x.shape),
            self_cache)
        # cross K/V computed once from encoder output at prefill time
        K, hd = cfg.n_kv, cfg.hd
        cdt = jnp.dtype(cfg.compute_dtype)
        cross = {
            "k": jnp.zeros((self.n_groups, batch, max_len, K, hd), cdt),
            "v": jnp.zeros((self.n_groups, batch, max_len, K, hd), cdt),
        }
        return {"groups": stacked, "cross": cross}

    def cache_specs(self, batch: int, max_len: int, mesh=None) -> PyTree:
        cfg = self.cfg
        mesh = mesh or self.mesh
        rules = cfg.logical_overrides
        tp = dict(getattr(mesh, "shape", {})).get("model", 1)
        axes = B.cache_logical_axes(cfg, "dec_x", tp)
        cache = jax.eval_shape(lambda: self._cache_defs(batch, max_len))
        specs = {}
        specs["groups"] = {
            bk: {ln: shd.spec_for(mesh, ("layers",) + tuple(axes[ln]),
                                  arr.shape, rules)
                 for ln, arr in leaves.items()}
            for bk, leaves in cache["groups"].items()}
        if tp > 1 and cfg.n_kv % tp == 0:
            xkv, xseq = "kv_heads", None
        else:
            xkv, xseq = None, "seq_shard"
        specs["cross"] = {
            ln: shd.spec_for(mesh, ("layers", "batch", xseq, xkv,
                                    "head_dim"), arr.shape, rules)
            for ln, arr in cache["cross"].items()}
        return specs

    def build_cross_cache(self, params, enc_out):
        """Project encoder output to per-layer cross K/V (prefill step)."""
        cfg = self.cfg
        src_pos = jnp.arange(enc_out.shape[1])

        def body(_, gp):
            from repro.models import attention as attn_mod
            k, v = attn_mod.project_kv(gp["b0_dec_x"]["xattn"], enc_out,
                                       cfg, src_pos, use_rope=False)
            return None, {"k": k, "v": v}

        _, cross = lax.scan(body, None, params["groups"])
        return cross

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = L.apply_embed(params["embed"], token[:, None], cfg)

        def body(x, inp):
            gp, gc, cross = inp
            x, new_gc = B.apply_decode(gp["b0_dec_x"], "dec_x", x,
                                       gc["b0_dec_x"], pos, cfg,
                                       cross_cache=cross)
            return x, {"b0_dec_x": new_gc}

        x, new_gc = lax.scan(body, x,
                             (params["groups"], cache["groups"],
                              cache["cross"]))
        x = L.apply_rmsnorm(params["final_norm"], x)
        logits = L.apply_unembed(params["embed"], x, cfg)[:, 0]
        return logits, {"groups": new_gc, "cross": cache["cross"]}


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    if cfg.enc_layers > 0:
        return EncDecModel(cfg, mesh)
    return Model(cfg, mesh)
