"""Shared neural building blocks: norms, RoPE, GLU MLPs.

Pure-functional: `*_defs(cfg)` declares parameters, `apply_*` consumes them.
All matmuls run in cfg.compute_dtype with f32 accumulation via
`preferred_element_type`; norms and softmax run in f32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


def cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


def gather_fsdp(w: jax.Array, mesh, logical_axes) -> jax.Array:
    """Explicit ZeRO-3 weight gather before use.

    FSDP shards weights' contraction dims over the data axis for storage;
    computing against a sharded contraction dim makes GSPMD emit partial-sum
    all-reduces of *activations* (huge).  Constraining the weight to its
    FSDP-free spec forces the cheap per-layer weight all-gather instead, and
    autodiff's transpose turns it into a reduce-scatter of the weight grads
    — the standard ZeRO-3 comm pattern.  No-op when mesh is None.
    """
    if mesh is None:
        return w
    from repro.dist import sharding as shd
    return shd.constrain(w, mesh, logical_axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(d: int, cfg) -> dict:
    return {"scale": ParamDef((d,), cfg.param_dtype, ("embed_nofsdp",),
                              init="ones")}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GLU MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_defs(d: int, ff: int, cfg) -> dict:
    return {
        "wi": ParamDef((d, ff), cfg.param_dtype, ("embed", "ffn")),
        "wg": ParamDef((d, ff), cfg.param_dtype, ("embed", "ffn")),
        "wo": ParamDef((ff, d), cfg.param_dtype, ("ffn", "embed")),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def apply_mlp(p: dict, x: jax.Array, cfg, mesh=None) -> jax.Array:
    dt = cdt(cfg)
    xd = x.astype(dt)
    wi = gather_fsdp(p["wi"].astype(dt), mesh, (None, "ffn"))
    wg = gather_fsdp(p["wg"].astype(dt), mesh, (None, "ffn"))
    wo = gather_fsdp(p["wo"].astype(dt), mesh, ("ffn", None))
    h = jnp.einsum("...d,df->...f", xd, wi,
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("...d,df->...f", xd, wg,
                   preferred_element_type=jnp.float32)
    h = (_act(cfg.act)(g) * h).astype(dt)
    out = jnp.einsum("...f,fd->...d", h, wo,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg) -> dict:
    d = {"tok": ParamDef((cfg.vocab, cfg.d_model), cfg.param_dtype,
                         ("vocab", "embed"), init="scaled", scale=0.02)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab), cfg.param_dtype,
                                ("embed", "vocab"), init="scaled",
                                scale=0.02)
    return d


def apply_embed(p: dict, tokens: jax.Array, cfg, mesh=None) -> jax.Array:
    w = gather_fsdp(p["tok"].astype(cdt(cfg)), mesh, ("vocab", None))
    return w[tokens]


def apply_unembed(p: dict, x: jax.Array, cfg, mesh=None) -> jax.Array:
    dt = cdt(cfg)
    if "unembed" in p:
        w = gather_fsdp(p["unembed"].astype(dt), mesh, (None, "vocab"))
    else:
        w = gather_fsdp(p["tok"].astype(dt), mesh, ("vocab", None)).T
    return jnp.einsum("...d,dv->...v", x.astype(dt), w,
                      preferred_element_type=jnp.float32)
