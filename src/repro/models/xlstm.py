"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM trains/prefills with a *chunkwise* algorithm — intra-chunk quadratic
attention-like compute + an inter-chunk recurrent (C, n, m) state — giving
O(S * c) cost instead of O(S^2); decode is an O(1) state update (this is
what makes the 524k decode cell runnable).  Exponential gating is
stabilized with the running max-term m as in the xLSTM paper.

sLSTM has genuine state-mixing recurrence (gates depend on h_{t-1}), so its
training path is a lax.scan over time; xlstm-1.3b uses it for 1 block in 8.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamDef

CONV_WIDTH = 4
CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_defs(cfg) -> dict:
    d = cfg.d_model
    di = 2 * d                       # projection factor 2
    h = cfg.n_heads
    dh = di // h
    return {
        "norm": L.rmsnorm_defs(d, cfg),
        "w_up": ParamDef((d, 2 * di), cfg.param_dtype, ("embed", "rnn")),
        "w_down": ParamDef((di, d), cfg.param_dtype, ("rnn", "embed")),
        "conv_w": ParamDef((CONV_WIDTH, di), cfg.param_dtype,
                           ("conv", "rnn"), init="scaled", scale=0.1),
        "conv_b": ParamDef((di,), cfg.param_dtype, ("rnn",), init="zeros"),
        # block-diagonal per-head q/k/v.  The v projection's OUTPUT dim
        # carries the "mlstm_dh" logical axis: v, C (on its value dim), and
        # h_out then shard over the model axis even though the head count
        # (4) cannot — the q/k side stays replicated, so the chunk
        # recurrence needs no cross-shard reduction at all (the s and den
        # terms contract only q/k dims).  See EXPERIMENTS.md §Perf iter. 3.
        "wq": ParamDef((h, dh, dh), cfg.param_dtype,
                       ("heads", "head_dim", None)),
        "wk": ParamDef((h, dh, dh), cfg.param_dtype,
                       ("heads", "head_dim", None)),
        "wv": ParamDef((h, dh, dh), cfg.param_dtype,
                       ("heads", "head_dim", "mlstm_dh")),
        "w_if": ParamDef((di, 2 * h), cfg.param_dtype, ("rnn",  None),
                         init="scaled", scale=0.02),
        "b_if": ParamDef((2 * h,), "float32", (None,), init="zeros"),
        "outnorm": ParamDef((di,), cfg.param_dtype, ("rnn",), init="ones"),
    }


def _mlstm_qkvif(p, x, cfg, mesh=None):
    """x: (B, S, D) -> q,k,v (B,S,H,dh), i,f logits (B,S,H), z gate (B,S,di)."""
    dt = L.cdt(cfg)
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    dh = di // h
    xn = L.apply_rmsnorm(p["norm"], x)
    w_up = L.gather_fsdp(p["w_up"].astype(dt), mesh, (None, "rnn"))
    up = jnp.einsum("bsd,de->bse", xn.astype(dt), w_up,
                    preferred_element_type=jnp.float32).astype(dt)
    xin, z = up[..., :di], up[..., di:]
    # causal conv + swish on the q/k source
    w = p["conv_w"].astype(dt)
    conv = xin * w[CONV_WIDTH - 1]
    for i in range(1, CONV_WIDTH):
        shifted = jnp.pad(xin, ((0, 0), (i, 0), (0, 0)))[:, :xin.shape[1]]
        conv = conv + shifted * w[CONV_WIDTH - 1 - i]
    conv = jax.nn.silu(conv + p["conv_b"].astype(dt))
    ch = conv.reshape(*conv.shape[:-1], h, dh)
    vh = xin.reshape(*xin.shape[:-1], h, dh)
    q = jnp.einsum("bshe,hef->bshf", ch, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k = jnp.einsum("bshe,hef->bshf", ch, p["wk"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bshe,hef->bshf", vh, p["wv"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    i_f = jnp.einsum("bse,ef->bsf", conv, p["w_if"].astype(dt),
                     preferred_element_type=jnp.float32) + p["b_if"]
    i_log, f_log = i_f[..., :h], i_f[..., h:]       # (B, S, H) f32
    return q, k, v, i_log, f_log, z


def _mlstm_chunk_scan(q, k, v, i_log, f_log, state):
    """Chunkwise mLSTM over one chunk per call, scanned over chunks.

    q,k,v: (B, nc, c, H, dh); i_log/f_log: (B, nc, c, H) f32.
    state: C (B,H,dh,dh), n (B,H,dh), m (B,H) f32.
    Returns outputs (B, nc, c, H, dh) and final state.
    """
    B, nc, c, H, dh = q.shape
    scale = 1.0 / math.sqrt(dh)

    def body(carry, inp):
        C_in, n_in, m_in = carry
        qc, kc, vc, il, fl = inp      # (B,c,H,dh)... (B,c,H)
        logf = jax.nn.log_sigmoid(fl)                    # (B,c,H)
        lc = jnp.cumsum(logf, axis=1)                    # inclusive
        bmax = lax.cummax(il - lc, axis=1)               # running max of i - lc
        m_j = lc + jnp.maximum(m_in[:, None, :], bmax)   # (B,c,H)
        # intra-chunk decay matrix:  D_js = lc_j - lc_s + i_s - m_j, s <= j
        djs = (lc[:, :, None, :] - lc[:, None, :, :]
               + il[:, None, :, :] - m_j[:, :, None, :])  # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri[None, :, :, None], jnp.exp(djs), 0.0)
        s = jnp.einsum("bjhd,bshd->bjsh", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        w = s * dmat                                      # (B,c,c,H)
        num_intra = jnp.einsum("bjsh,bshd->bjhd", w, vc.astype(jnp.float32))
        den_intra = jnp.sum(w, axis=2)                    # (B,c,H)
        # inter-chunk: factor exp(lc_j + m_in - m_j)
        inter = jnp.exp(lc + m_in[:, None, :] - m_j)      # (B,c,H)
        qf = qc.astype(jnp.float32) * scale
        num_inter = jnp.einsum("bjhd,bhde->bjhe", qf, C_in) * inter[..., None]
        den_inter = jnp.einsum("bjhd,bhd->bjh", qf, n_in) * inter
        num = num_intra + num_inter
        den = den_intra + den_inter
        h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # state update to end of chunk
        lc_end = lc[:, -1, :]                             # (B,H)
        m_out = lc_end + jnp.maximum(m_in, bmax[:, -1, :])
        carry_f = jnp.exp(lc_end + m_in - m_out)          # (B,H)
        wgt = jnp.exp(lc_end[:, None, :] - lc + il - m_out[:, None, :])
        C_out = (C_in * carry_f[..., None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", wgt,
                              kc.astype(jnp.float32), vc.astype(jnp.float32)))
        n_out = (n_in * carry_f[..., None]
                 + jnp.einsum("bsh,bshd->bhd", wgt, kc.astype(jnp.float32)))
        return (C_out, n_out, m_out), h_out

    elems = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_log, f_log))
    state, outs = lax.scan(body, state, elems)
    return jnp.moveaxis(outs, 0, 1), state


def mlstm_init_state(cfg, batch: int) -> dict:
    di = 2 * cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, di),
                          jnp.dtype(cfg.compute_dtype)),
    }


def mlstm_apply_train(p: dict, x: jax.Array, cfg, mesh=None) -> jax.Array:
    B, S, D = x.shape
    di = 2 * D
    h = cfg.n_heads
    dh = di // h
    q, k, v, il, fl, z = _mlstm_qkvif(p, x, cfg, mesh)
    c = min(CHUNK, S)
    while S % c:
        c -= 1
    nc = S // c
    rs = lambda t: t.reshape(B, nc, c, *t.shape[2:])
    state = {k2: v2 for k2, v2 in mlstm_init_state(cfg, B).items()
             if k2 != "conv"}
    outs, _ = _mlstm_chunk_scan(rs(q), rs(k), rs(v), rs(il), rs(fl),
                                (state["C"], state["n"], state["m"]))
    hout = outs.reshape(B, S, h, dh).reshape(B, S, di)
    dt = L.cdt(cfg)
    # per-channel group norm then output gate
    hn = (hout * jax.lax.rsqrt(
        jnp.mean(hout * hout, axis=-1, keepdims=True) + 1e-6)
          * p["outnorm"].astype(jnp.float32))
    gated = hn.astype(dt) * jax.nn.silu(z)
    w_down = L.gather_fsdp(p["w_down"].astype(dt), mesh, ("rnn", None))
    out = jnp.einsum("bse,ed->bsd", gated, w_down,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def mlstm_apply_decode(p: dict, x: jax.Array, cache: dict, cfg, mesh=None):
    """x: (B, 1, D); exact recurrent step (O(1) in sequence length)."""
    B, _, D = x.shape
    di = 2 * D
    h = cfg.n_heads
    dh = di // h
    dt = L.cdt(cfg)
    xn = L.apply_rmsnorm(p["norm"], x)
    up = jnp.einsum("bsd,de->bse", xn.astype(dt), p["w_up"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    xin, z = up[..., :di], up[..., di:]
    hist = jnp.concatenate([cache["conv"], xin], axis=1)     # (B, 4, di)
    w = p["conv_w"].astype(dt)
    conv = jax.nn.silu(jnp.einsum("bwe,we->be", hist, w)
                       + p["conv_b"].astype(dt))
    ch = conv.reshape(B, h, dh)
    vh = xin[:, 0].reshape(B, h, dh)
    q = jnp.einsum("bhe,hef->bhf", ch, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bhe,hef->bhf", ch, p["wk"].astype(dt),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bhe,hef->bhf", vh, p["wv"].astype(dt),
                   preferred_element_type=jnp.float32)
    i_f = jnp.einsum("be,ef->bf", conv, p["w_if"].astype(dt),
                     preferred_element_type=jnp.float32) + p["b_if"]
    il, fl = i_f[..., :h], i_f[..., h:]                      # (B, h)
    logf = jax.nn.log_sigmoid(fl)
    m_new = jnp.maximum(logf + cache["m"], il)
    i_p = jnp.exp(il - m_new)
    f_p = jnp.exp(logf + cache["m"] - m_new)
    C = (cache["C"] * f_p[..., None, None]
         + i_p[..., None, None] * k[..., :, None] * v[..., None, :])
    n = cache["n"] * f_p[..., None] + i_p[..., None] * k
    qf = q * (1.0 / math.sqrt(dh))
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    hflat = hout.reshape(B, di)
    hn = (hflat * jax.lax.rsqrt(
        jnp.mean(hflat * hflat, axis=-1, keepdims=True) + 1e-6)
          * p["outnorm"].astype(jnp.float32))
    gated = hn.astype(dt) * jax.nn.silu(z[:, 0])
    out = jnp.einsum("be,ed->bd", gated, p["w_down"].astype(dt),
                     preferred_element_type=jnp.float32)[:, None]
    new_cache = {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "norm": L.rmsnorm_defs(d, cfg),
        "w_in": ParamDef((d, 4, h, dh), cfg.param_dtype,
                         ("embed", None, "heads", "head_dim")),
        "r_h": ParamDef((h, dh, 4, dh), cfg.param_dtype,
                        ("heads", "head_dim", None, "head_dim"),
                        init="scaled", scale=0.02),
        "bias": ParamDef((4, h, dh), "float32", (None, "heads", "head_dim"),
                         init="zeros"),
        "w_out": ParamDef((d, d), cfg.param_dtype, ("embed", "ffn")),
        "outnorm": ParamDef((d,), cfg.param_dtype, ("embed_nofsdp",),
                            init="ones"),
    }


def slstm_init_state(cfg, batch: int) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}


def _slstm_cell(p, gates_x, state):
    """gates_x: (B, 4, h, dh) input contribution; state mixing via r_h."""
    c, n, hs, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,hdge->bghe", hs, p["r_h"].astype(jnp.float32))
    g = gates_x.astype(jnp.float32) + rec + p["bias"]
    zt = jnp.tanh(g[:, 0])
    il = g[:, 1]
    fl = jax.nn.log_sigmoid(g[:, 2])
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(fl + m, il)
    i_p = jnp.exp(il - m_new)
    f_p = jnp.exp(fl + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply_train(p: dict, x: jax.Array, cfg, mesh=None) -> jax.Array:
    B, S, D = x.shape
    h = cfg.n_heads
    dh = D // h
    dt = L.cdt(cfg)
    xn = L.apply_rmsnorm(p["norm"], x)
    w_in = L.gather_fsdp(p["w_in"].astype(dt), mesh,
                         (None, None, "heads", "head_dim"))
    gx = jnp.einsum("bsd,dghe->bsghe", xn.astype(dt),
                    w_in,
                    preferred_element_type=jnp.float32)   # (B,S,4,h,dh)

    def body(state, g_t):
        state = _slstm_cell(p, g_t, state)
        return state, state["h"]

    state0 = slstm_init_state(cfg, B)
    _, hs = lax.scan(body, state0, jnp.moveaxis(gx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)          # f32
    hn = (hs * jax.lax.rsqrt(jnp.mean(hs * hs, -1, keepdims=True) + 1e-6)
          * p["outnorm"].astype(jnp.float32))
    w_out = L.gather_fsdp(p["w_out"].astype(dt), mesh, (None, "ffn"))
    out = jnp.einsum("bsd,de->bse", hn.astype(dt), w_out,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def slstm_apply_decode(p: dict, x: jax.Array, cache: dict, cfg, mesh=None):
    B, _, D = x.shape
    h = cfg.n_heads
    dh = D // h
    dt = L.cdt(cfg)
    xn = L.apply_rmsnorm(p["norm"], x)
    gx = jnp.einsum("bsd,dghe->bsghe", xn.astype(dt), p["w_in"].astype(dt),
                    preferred_element_type=jnp.float32)[:, 0]
    state = _slstm_cell(p, gx, cache)
    hs = state["h"].reshape(B, D)
    hn = (hs * jax.lax.rsqrt(jnp.mean(hs * hs, -1, keepdims=True) + 1e-6)
          * p["outnorm"].astype(jnp.float32))
    out = jnp.einsum("bd,de->be", hn.astype(dt), p["w_out"].astype(dt),
                     preferred_element_type=jnp.float32)[:, None]
    return out.astype(x.dtype), state
