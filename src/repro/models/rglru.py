"""RecurrentGemma / Griffin recurrent block: causal conv + RG-LRU.

The RG-LRU is a gated diagonal linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),
which trains with a parallel associative scan (log-depth on TPU) and decodes
with an O(1) state update — this is what makes the 524k-token decode cell
runnable for this family (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamDef

C_FACTOR = 8.0
CONV_WIDTH = 4


def rglru_defs(cfg) -> dict:
    d = cfg.d_model
    dr = d  # recurrent width = d_model (Griffin-2B choice)
    return {
        "wg": ParamDef((d, dr), cfg.param_dtype, ("embed", "rnn")),
        "wr": ParamDef((d, dr), cfg.param_dtype, ("embed", "rnn")),
        "wo": ParamDef((dr, d), cfg.param_dtype, ("rnn", "embed")),
        "conv_w": ParamDef((CONV_WIDTH, dr), cfg.param_dtype,
                           ("conv", "rnn"), init="scaled", scale=0.1),
        "conv_b": ParamDef((dr,), cfg.param_dtype, ("rnn",), init="zeros"),
        # per-channel gate projections (diagonal+bias, Griffin block-diag
        # simplified to channelwise)
        "wa": ParamDef((dr,), cfg.param_dtype, ("rnn",), init="scaled",
                       scale=0.5),
        "ba": ParamDef((dr,), cfg.param_dtype, ("rnn",), init="zeros"),
        "wx": ParamDef((dr,), cfg.param_dtype, ("rnn",), init="scaled",
                       scale=0.5),
        "bx": ParamDef((dr,), cfg.param_dtype, ("rnn",), init="zeros"),
        "lam": ParamDef((dr,), "float32", ("rnn",), init="scaled",
                        scale=0.2),
    }


def _gates(p, u):
    """u: (..., dr) conv output -> (a, gated_input) in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["wa"].astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["wx"].astype(jnp.float32)
                       + p["bx"].astype(jnp.float32))
    # softplus(lam - 4): initialized near 0.018 => a ~= exp(-0.14 r) in
    # [0.87, 1.0), the paper's "slow decay at init" regime.
    decay = C_FACTOR * jax.nn.softplus(p["lam"] - 4.0)
    log_a = -decay * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def _conv_train(p, x):
    """Causal depthwise conv, width 4.  x: (B, S, dr)."""
    dt = x.dtype
    w = p["conv_w"].astype(dt)
    out = x * w[CONV_WIDTH - 1]
    for i in range(1, CONV_WIDTH):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[CONV_WIDTH - 1 - i]
    return out + p["conv_b"].astype(dt)


def apply_train(p: dict, x: jax.Array, cfg, mesh=None) -> jax.Array:
    dt = L.cdt(cfg)
    xd = x.astype(dt)
    wg_ = L.gather_fsdp(p["wg"].astype(dt), mesh, (None, "rnn"))
    wr_ = L.gather_fsdp(p["wr"].astype(dt), mesh, (None, "rnn"))
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dr->bsr", xd, wg_,
        preferred_element_type=jnp.float32)).astype(dt)
    u = jnp.einsum("bsd,dr->bsr", xd, wr_,
                   preferred_element_type=jnp.float32).astype(dt)
    u = _conv_train(p, u)
    a, b = _gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(dt)
    wo_ = L.gather_fsdp(p["wo"].astype(dt), mesh, ("rnn", None))
    out = jnp.einsum("bsr,rd->bsd", gate * h, wo_,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def init_cache(cfg, batch: int) -> dict:
    dr = cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, dr), jnp.dtype(cfg.compute_dtype)),
        "h": jnp.zeros((batch, dr), jnp.float32),
    }


def apply_decode(p: dict, x: jax.Array, cache: dict, cfg, mesh=None):
    """x: (B, 1, D) -> (out (B, 1, D), new cache).  O(1) per step."""
    dt = L.cdt(cfg)
    xd = x.astype(dt)
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dr->bsr", xd, p["wg"].astype(dt),
        preferred_element_type=jnp.float32)).astype(dt)
    u = jnp.einsum("bsd,dr->bsr", xd, p["wr"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)[:, 0]
    # conv over [cache, u]
    w = p["conv_w"].astype(dt)
    hist = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B, 4, dr)
    u_conv = jnp.einsum("bwr,wr->br", hist, w) + p["conv_b"].astype(dt)
    a, b = _gates(p, u_conv)
    h = a * cache["h"] + b
    out = jnp.einsum("bsr,rd->bsd", (gate[:, 0] * h.astype(dt))[:, None],
                     p["wo"].astype(dt),
                     preferred_element_type=jnp.float32)
    new_cache = {"conv": hist[:, 1:], "h": h}
    return out.astype(x.dtype), new_cache
