"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Top-k routing -> argsort token copies by expert -> capacity-bucketed
(E, C, d) einsum -> unsort + gate-weighted combine.  Expert weights are
sharded experts->model (EP) and d_model->data (FSDP); the (E, C, d) dispatch
buffer is sharding-constrained onto the expert axis so GSPMD inserts the
token all-to-all.  Dropped tokens (beyond capacity) route to a trash slot
and contribute zeros, Switch-style.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import layers as L
from repro.models.params import ParamDef


def moe_defs(cfg) -> dict:
    m, d = cfg.moe, cfg.d_model
    E, f = m.num_experts, m.d_expert
    defs = {
        "router": ParamDef((d, E), "float32", ("embed_nofsdp", "experts"),
                           init="scaled", scale=0.02),
        "wi": ParamDef((E, d, f), cfg.param_dtype,
                       ("experts", "expert_in", "ffn")),
        "wg": ParamDef((E, d, f), cfg.param_dtype,
                       ("experts", "expert_in", "ffn")),
        "wo": ParamDef((E, f, d), cfg.param_dtype,
                       ("experts", "ffn", "expert_in")),
    }
    if m.shared_expert:
        defs["shared"] = L.mlp_defs(d, f, cfg)
    return defs


def _n_groups(mesh, T: int) -> int:
    """Routing groups = data shards: all sort/scatter index math stays
    group-local so GSPMD keeps the dispatch batch-sharded.  A GLOBAL
    argsort over (T*k,) forces replicated (T*k, D) dispatch buffers whose
    f32 gradients are all-reduced — for moonshot train_4k that single
    mistake was 6.4 GB per all-reduce and a 676 s collective term
    (EXPERIMENTS.md §Perf iteration 4)."""
    if mesh is None:
        return 1
    g = dict(getattr(mesh, "shape", {})).get("data", 1)
    pod = dict(getattr(mesh, "shape", {})).get("pod", 1)
    g *= pod
    return g if T % g == 0 else 1


def _route_group(xt, router, E, k, capacity, dt):
    """Per-group routing: xt (Tg, D) -> dispatch buffer + combine indices."""
    Tg, D = xt.shape
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_idx.reshape(Tg * k)
    flat_gate = gate_vals.reshape(Tg * k)
    order = jnp.argsort(flat_expert)                         # stable
    sorted_expert = flat_expert[order]
    src_token = order // k

    seg_start = jnp.searchsorted(sorted_expert,
                                 jnp.arange(E, dtype=sorted_expert.dtype))
    pos_in_seg = jnp.arange(Tg * k) - seg_start[sorted_expert]
    keep = pos_in_seg < capacity
    slot = sorted_expert * capacity + jnp.minimum(pos_in_seg, capacity - 1)
    slot = jnp.where(keep, slot, E * capacity)               # trash slot

    # Dispatch as index inversion + row gather: the scatter runs on (E*C,)
    # int32 indices only (no D width), and the D-wide data movement is a
    # gather whose gradient is a unique-index scatter-add — GSPMD keeps
    # both group-local.  (A D-wide scatter-set here costs ~4x in backward
    # collectives from its duplicate/drop masking: §Perf iteration 4b.)
    inv = jnp.full((E * capacity + 1,), Tg, jnp.int32)       # default: pad row
    inv = inv.at[slot].set(src_token.astype(jnp.int32), mode="drop")
    xt_ext = jnp.concatenate([xt.astype(dt), jnp.zeros((1, D), dt)], axis=0)
    h = xt_ext[inv[:-1]].reshape(E, capacity, D)
    return (h, slot, src_token, flat_gate, order, keep, probs, flat_expert,
            logits)


def _combine_group(y, slot, src_token, flat_gate, order, Tg, D, dt):
    E_cap = y.shape[0] * y.shape[1]
    y_flat = jnp.concatenate([y.reshape(E_cap, D),
                              jnp.zeros((1, D), dt)], axis=0)
    gathered = y_flat[slot]                                   # (Tg*k, D)
    weighted = gathered * flat_gate[order][:, None].astype(dt)
    return jnp.zeros((Tg, D), jnp.float32).at[src_token].add(
        weighted.astype(jnp.float32))


def apply_moe(p: dict, x: jax.Array, cfg, mesh=None):
    """x: (B, S, D) -> (out (B, S, D), aux_losses dict)."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    B, S, D = x.shape
    T = B * S
    dt = L.cdt(cfg)
    G = _n_groups(mesh, T)
    Tg = T // G

    capacity = int(math.ceil(Tg * k / E * m.capacity_factor))
    capacity = max(capacity, 1)
    if S == 1:
        # decode: never drop a token (worst case: whole group -> one expert)
        capacity = Tg

    xg = x.reshape(G, Tg, D)
    if mesh is not None:
        xg = shd.constrain(xg, mesh, ("batch", None, None))

    route = jax.vmap(
        lambda xt: _route_group(xt, p["router"], E, k, capacity, dt))
    (h, slot, src_token, flat_gate, order, keep, probs, flat_expert,
     logits) = route(xg)
    # h: (G, E, C, D) — group dim batch-sharded, expert dim model-sharded;
    # the boundary reshard below IS the MoE token all-to-all.
    if mesh is not None:
        h = shd.constrain(h, mesh, ("batch", "experts", None, None))

    # ZeRO-3: gather the FSDP (data-axis) shards of the expert weights
    # before the einsums so GSPMD all-gathers weights rather than
    # all-reducing (G, E, C, f) partials (see layers.gather_fsdp).
    wi = L.gather_fsdp(p["wi"].astype(dt), mesh, ("experts", None, "ffn"))
    wg = L.gather_fsdp(p["wg"].astype(dt), mesh, ("experts", None, "ffn"))
    wo = L.gather_fsdp(p["wo"].astype(dt), mesh, ("experts", "ffn", None))
    a = jnp.einsum("gecd,edf->gecf", h, wi,
                   preferred_element_type=jnp.float32)
    gt = jnp.einsum("gecd,edf->gecf", h, wg,
                    preferred_element_type=jnp.float32)
    y = jnp.einsum("gecf,efd->gecd", (jax.nn.silu(gt) * a).astype(dt), wo,
                   preferred_element_type=jnp.float32).astype(dt)
    if mesh is not None:
        y = shd.constrain(y, mesh, ("batch", "experts", None, None))

    combine = jax.vmap(
        lambda yg, sl, st, fg, od: _combine_group(yg, sl, st, fg, od, Tg, D,
                                                  dt))
    out = combine(y, slot, src_token, flat_gate, order)       # (G, Tg, D) f32
    if mesh is not None:
        out = shd.constrain(out, mesh, ("batch", None, None))
    out = out.astype(x.dtype).reshape(B, S, D)

    if m.shared_expert:
        out = out + L.apply_mlp(p["shared"], x, cfg)

    # aux: Switch-style load-balance + router z-loss (group-averaged)
    me = probs.reshape(G * Tg, E).mean(axis=0)                # (E,)
    assign = jnp.zeros((E,), jnp.float32).at[flat_expert.reshape(-1)].add(
        1.0) / (T * k)
    aux = {
        "load_balance": E * jnp.sum(me * assign),
        "router_z": jnp.mean(
            jax.scipy.special.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return out, aux
