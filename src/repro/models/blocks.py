"""Block registry: one interface over all mixing/FFN layer families.

Block types:
  dense   — causal GQA attention + GLU MLP
  moe     — causal GQA attention + routed-expert FFN
  attn    — sliding-window attention + MLP (hybrid patterns)
  rglru   — RG-LRU recurrence + MLP (RecurrentGemma)
  mlstm   — xLSTM matrix-memory block (self-contained)
  slstm   — xLSTM scalar-memory block (self-contained)
  enc     — bidirectional attention + MLP (encoder stacks)
  dec_x   — causal self-attention + cross-attention + MLP (decoder stacks)

Each type provides defs / train / decode / cache-init so the model can scan
over heterogeneous layer patterns with a single compiled group body.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod

ATTN_TYPES = ("dense", "moe", "attn", "enc", "dec_x")


def block_defs(cfg, btype: str) -> dict:
    d = cfg.d_model
    if btype in ATTN_TYPES:
        defs = {
            "ln1": L.rmsnorm_defs(d, cfg),
            "attn": attn_mod.attn_defs(cfg),
            "ln2": L.rmsnorm_defs(d, cfg),
        }
        if btype == "dec_x":
            defs["lnx"] = L.rmsnorm_defs(d, cfg)
            defs["xattn"] = attn_mod.attn_defs(cfg, cross=True)
        if btype == "moe":
            defs["ffn"] = moe_mod.moe_defs(cfg)
        else:
            defs["ffn"] = L.mlp_defs(d, cfg.d_ff, cfg)
        return defs
    if btype == "rglru":
        return {
            "ln1": L.rmsnorm_defs(d, cfg),
            "rec": rglru_mod.rglru_defs(cfg),
            "ln2": L.rmsnorm_defs(d, cfg),
            "ffn": L.mlp_defs(d, cfg.d_ff, cfg),
        }
    if btype == "mlstm":
        return {"cell": xlstm_mod.mlstm_defs(cfg)}
    if btype == "slstm":
        return {"cell": xlstm_mod.slstm_defs(cfg)}
    raise ValueError(btype)


def _window_for(cfg, btype: str) -> Optional[int]:
    return cfg.window if btype == "attn" else None


def apply_train(p: dict, btype: str, x: jax.Array, cfg, *,
                positions: jax.Array, mesh=None, enc_out=None,
                causal: bool = True):
    """Full-sequence application.  Returns (x, aux_losses dict)."""
    aux = {}
    if btype in ATTN_TYPES:
        h = L.apply_rmsnorm(p["ln1"], x)
        q = attn_mod.project_q(p["attn"], h, cfg, positions)
        k, v = attn_mod.project_kv(p["attn"], h, cfg, positions)
        o = attn_mod.attend(q, k, v, causal=(causal and btype != "enc"),
                            window=_window_for(cfg, btype), mesh=mesh)
        x = x + attn_mod.apply_out(p["attn"], o, cfg).astype(x.dtype)
        if btype == "dec_x":
            hx = L.apply_rmsnorm(p["lnx"], x)
            qx = attn_mod.project_q(p["xattn"], hx, cfg, positions,
                                    use_rope=False)
            src_pos = jnp.arange(enc_out.shape[1])
            kx, vx = attn_mod.project_kv(p["xattn"], enc_out, cfg, src_pos,
                                         use_rope=False)
            ox = attn_mod.attend(qx, kx, vx, causal=False)
            x = x + attn_mod.apply_out(p["xattn"], ox, cfg).astype(x.dtype)
        h2 = L.apply_rmsnorm(p["ln2"], x)
        if btype == "moe":
            f, aux = moe_mod.apply_moe(p["ffn"], h2, cfg, mesh)
        else:
            f = L.apply_mlp(p["ffn"], h2, cfg)
        x = x + f.astype(x.dtype)
        return x, aux
    if btype == "rglru":
        h = L.apply_rmsnorm(p["ln1"], x)
        x = x + rglru_mod.apply_train(p["rec"], h, cfg).astype(x.dtype)
        h2 = L.apply_rmsnorm(p["ln2"], x)
        x = x + L.apply_mlp(p["ffn"], h2, cfg).astype(x.dtype)
        return x, aux
    if btype == "mlstm":
        return x + xlstm_mod.mlstm_apply_train(p["cell"], x, cfg
                                               ).astype(x.dtype), aux
    if btype == "slstm":
        return x + xlstm_mod.slstm_apply_train(p["cell"], x, cfg
                                               ).astype(x.dtype), aux
    raise ValueError(btype)


def init_cache(cfg, btype: str, batch: int, max_len: int) -> dict:
    cdt = jnp.dtype(cfg.compute_dtype)
    K, hd = cfg.n_kv, cfg.hd
    if btype in ATTN_TYPES:
        t = max_len
        w = _window_for(cfg, btype)
        if w is not None:
            t = min(t, w)
        cache = {
            "k": jnp.zeros((batch, t, K, hd), cdt),
            "v": jnp.zeros((batch, t, K, hd), cdt),
            "pos": jnp.full((t,), -1, jnp.int32),
        }
        return cache
    if btype == "rglru":
        return rglru_mod.init_cache(cfg, batch)
    if btype == "mlstm":
        return xlstm_mod.mlstm_init_state(cfg, batch)
    if btype == "slstm":
        return xlstm_mod.slstm_init_state(cfg, batch)
    raise ValueError(btype)


def cache_logical_axes(cfg, btype: str, tp: int = 1) -> dict:
    """Logical axes for cache leaves.

    Prefer sharding KV heads over the model axis; when the head count does
    not divide it (extreme GQA: glm4 kv=2, llama4 kv=8 on a 16-way axis),
    shard the cache's *sequence* dimension instead — decode attention then
    reduces partial softmax terms across the model axis (GSPMD inserts the
    collectives).
    """
    if btype in ATTN_TYPES:
        if tp > 1 and cfg.n_kv % tp == 0:
            kv, seq = "kv_heads", None
        else:
            kv, seq = None, "seq_shard"
        return {"k": ("batch", seq, kv, "head_dim"),
                "v": ("batch", seq, kv, "head_dim"),
                "pos": (None,)}
    if btype == "rglru":
        return {"conv": ("batch", None, "rnn"), "h": ("batch", "rnn")}
    if btype == "mlstm":
        return {"C": ("batch", "heads", None, None),
                "n": ("batch", "heads", None),
                "m": ("batch", "heads"),
                "conv": ("batch", None, "rnn")}
    if btype == "slstm":
        return {k: ("batch", "heads", "head_dim")
                for k in ("c", "n", "h", "m")}
    raise ValueError(btype)


def apply_decode(p: dict, btype: str, x: jax.Array, cache: dict,
                 pos: jax.Array, cfg, *, cross_cache: Optional[dict] = None):
    """Single-token application.  x: (B, 1, D).  Returns (x, new_cache)."""
    if btype in ATTN_TYPES:
        h = L.apply_rmsnorm(p["ln1"], x)
        positions = pos[None]
        q = attn_mod.project_q(p["attn"], h, cfg, positions)
        k, v = attn_mod.project_kv(p["attn"], h, cfg, positions)
        w = _window_for(cfg, btype)
        kc, vc, pc = attn_mod.cache_update(
            cache["k"], cache["v"], cache["pos"], k, v, pos, window=w)
        o = attn_mod.attend_decode(q, kc, vc, pc, pos, window=w)
        x = x + attn_mod.apply_out(p["attn"], o, cfg).astype(x.dtype)
        new_cache = {"k": kc, "v": vc, "pos": pc}
        if btype == "dec_x":
            hx = L.apply_rmsnorm(p["lnx"], x)
            qx = attn_mod.project_q(p["xattn"], hx, cfg, positions,
                                    use_rope=False)
            src_len = cross_cache["k"].shape[1]
            ox = attn_mod.attend_decode(
                qx, cross_cache["k"], cross_cache["v"],
                jnp.arange(src_len), jnp.asarray(src_len, jnp.int32))
            x = x + attn_mod.apply_out(p["xattn"], ox, cfg).astype(x.dtype)
        h2 = L.apply_rmsnorm(p["ln2"], x)
        if btype == "moe":
            f, _ = moe_mod.apply_moe(p["ffn"], h2, cfg)
        else:
            f = L.apply_mlp(p["ffn"], h2, cfg)
        x = x + f.astype(x.dtype)
        return x, new_cache
    if btype == "rglru":
        h = L.apply_rmsnorm(p["ln1"], x)
        o, new_cache = rglru_mod.apply_decode(p["rec"], h, cache, cfg)
        x = x + o.astype(x.dtype)
        h2 = L.apply_rmsnorm(p["ln2"], x)
        x = x + L.apply_mlp(p["ffn"], h2, cfg).astype(x.dtype)
        return x, new_cache
    if btype == "mlstm":
        o, new_cache = xlstm_mod.mlstm_apply_decode(p["cell"], x, cache, cfg)
        return x + o.astype(x.dtype), new_cache
    if btype == "slstm":
        o, new_cache = xlstm_mod.slstm_apply_decode(p["cell"], x, cache, cfg)
        return x + o.astype(x.dtype), new_cache
    raise ValueError(btype)
