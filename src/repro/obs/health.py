"""`HealthReport` — "what is my integrity health right now?".

The report folds the pool's live degradation signals into one
green / degraded / critical verdict with named reasons, built entirely
from host-known state (straggler drops, adaptive-window pressure, scrub
findings, syndrome budget) — asking for health never touches the device,
so a monitoring loop can poll it at any cadence without perturbing the
commit path.

Status semantics (tests/test_obs.py pins the transitions):

  * critical — the pool cannot currently guarantee its fault contract:
    the syndrome budget was exhausted (an e > r storm hit; online
    recovery refused and the pool is waiting on the checkpoint tier), a
    post-recovery re-verify failed (residual corruption after a
    reconstruction), or a scrub found corruption it could not repair.
  * degraded — protected but impaired: replicas dropped by the
    straggler policy, failure suspicion outstanding (a recovery or
    suspect scrub collapsed the adaptive window and no clean scrub has
    cleared it yet), or the window is pressure-collapsed below its
    ceiling.
  * green — none of the above.

Healing is symmetric: straggler drops clear when the policy re-admits
the replica; suspicion clears on the next clean scrub/pre-check; budget
exhaustion clears when the pool is re-armed (`pool.init` after the
checkpoint-tier restore).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

GREEN, DEGRADED, CRITICAL = "green", "degraded", "critical"


@dataclasses.dataclass
class HealthReport:
    status: str                          # green | degraded | critical
    reasons: List[str]                   # why, one phrase per signal
    # window state
    window: int                          # current adaptive window
    max_window: int                      # configured ceiling
    # degradation signals
    dropped_replicas: List[int]
    suspect: bool                        # failure suspicion outstanding
    # syndrome budget
    redundancy: int                      # configured stack height r
    budget_remaining: int                # 0 after an e > r exhaust
    budget_exhausted: bool
    # scrub findings
    scrub_coverage: Optional[dict]       # Scrubber.coverage() or None
    unrepaired_pages: int                # bad pages the last scrub could
                                         # not repair
    reverify_failed: bool                # last recovery's re-verify
    # recovery history (host counters)
    recoveries: int
    recovery_followups: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def assess(*, window: int, max_window: int, dropped_replicas,
           suspect: bool, redundancy: int, budget_exhausted: bool,
           scrub_coverage: Optional[dict], unrepaired_pages: int,
           reverify_failed: bool, recoveries: int,
           recovery_followups: int) -> HealthReport:
    """Fold the raw signals into a HealthReport (pure function — the
    Pool gathers the inputs, this ranks them)."""
    dropped = sorted(int(r) for r in dropped_replicas)
    reasons: List[str] = []
    status = GREEN
    if dropped:
        status = DEGRADED
        reasons.append(f"straggler policy dropped replicas {dropped}")
    if suspect:
        status = DEGRADED
        reasons.append("failure suspicion outstanding "
                       "(no clean scrub since the last fault)")
    if max_window > 1 and window < max_window:
        status = DEGRADED
        reasons.append(f"adaptive window collapsed ({window} < "
                       f"ceiling {max_window})")
    if unrepaired_pages:
        status = CRITICAL
        reasons.append(f"{unrepaired_pages} corrupted page(s) the last "
                       "scrub could not repair")
    if reverify_failed:
        status = CRITICAL
        reasons.append("post-recovery re-verify failed "
                       "(residual corruption)")
    if budget_exhausted:
        status = CRITICAL
        reasons.append("syndrome budget exhausted (e > r storm; "
                       "restore from the checkpoint tier and re-arm)")
    return HealthReport(
        status=status, reasons=reasons, window=int(window),
        max_window=int(max_window), dropped_replicas=dropped,
        suspect=bool(suspect), redundancy=int(redundancy),
        budget_remaining=0 if budget_exhausted else int(redundancy),
        budget_exhausted=bool(budget_exhausted),
        scrub_coverage=scrub_coverage,
        unrepaired_pages=int(unrepaired_pages),
        reverify_failed=bool(reverify_failed),
        recoveries=int(recoveries),
        recovery_followups=int(recovery_followups))
