"""repro.obs — the pool telemetry plane.

Three cooperating pieces, all host-side and jax-free so they can never
perturb a compiled program (the §facade zero-byte invariant):

  * `MetricsRegistry` (obs/metrics.py) — counters / gauges /
    fixed-bucket histograms with online p50/p99.  Every `Pool` owns one;
    the engines, scrubber, straggler policy and recovery paths publish
    into it.
  * `Tracer` (obs/trace.py) — structured JSONL span events whose ids
    tie a fault injection to its recovery solve, re-verify and queued
    follow-ups; `validate_events` checks well-formedness
    (scripts/trace_check.py is the CLI).
  * `HealthReport` (obs/health.py) — green/degraded/critical from the
    window state, straggler drops, scrub findings and syndrome budget;
    `prometheus_text` (obs/export.py) renders the registry for scraping.

Entry points on a live pool: `pool.metrics`, `pool.tracer`,
`pool.stats()`, `pool.health()`; launchers expose --metrics-dir /
--trace-dir.  This module is import-light on purpose (no jax at import
time) — safe to import before XLA flags are set, like repro itself.
"""
from repro.obs.health import CRITICAL, DEGRADED, GREEN, HealthReport
from repro.obs.metrics import (Counter, Gauge, Histogram, LabeledRegistry,
                               MetricsRegistry, default_buckets)
from repro.obs.trace import Tracer, load_jsonl, validate_events
from repro.obs.export import prometheus_text, serve_metrics, write_metrics

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LabeledRegistry",
    "default_buckets",
    "Tracer", "load_jsonl", "validate_events",
    "HealthReport", "GREEN", "DEGRADED", "CRITICAL",
    "prometheus_text", "serve_metrics", "write_metrics",
]
