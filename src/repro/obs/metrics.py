"""Low-overhead host-side metrics registry (the telemetry plane's core).

Everything here is plain-Python host state: incrementing a counter or
observing a histogram sample is a dict lookup plus a float add — no jax
import, no device traffic, nothing that could change a compiled program.
That is the load-bearing property: the `Pool` commit path publishes into
this registry on every transaction, and the §facade invariant (zero
compiled-byte overhead, benchmarks/obs_overhead.py) only holds because
instrumentation never touches a jitted function or a device value.
Device-resident quantities (the step counter, scrub verdicts) are
published only at boundaries that already fetch them (scrub, recovery,
`pool.stats()`), never from the steady-state commit loop.

Metric vocabulary (Prometheus-style, see obs/export.py):

  * Counter   — monotone float (`inc`), e.g. pool_commits_total
  * Gauge     — last-write-wins float (`set`/`inc`), e.g. pool_window
  * Histogram — fixed log-spaced buckets with online percentile
    estimation (`observe`, `percentile`); count/sum/min/max ride along
    so the exporter can emit the classic _count/_sum series.

Labels are keyword arguments on the getter; each distinct label set is
its own child metric, so `registry.counter("scrub_runs_total",
kind="full")` and `kind="precheck"` count independently (exactly the
Prometheus data model).  Getters are idempotent — fetching an existing
(name, labels) pair returns the same object — so call sites just ask
for what they need and never pre-register anything.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def default_buckets(lo: float = 1e-3, hi: float = 1e5,
                    per_decade: int = 8) -> List[float]:
    """Log-spaced bucket upper bounds covering [lo, hi].

    The default spans 1 us .. 100 s when samples are milliseconds — wide
    enough for every wall-clock series the pool publishes — at 8 buckets
    per decade (adjacent edges ~1.33x apart, so percentile estimates
    land within ~15% of the true sample; tests pin this against numpy).
    """
    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * 10 ** (k / per_decade) for k in range(n + 1)]


class Counter:
    """Monotone counter.  `inc` only; negative increments are a bug."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counters are monotone (inc {n})"
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram with online percentile estimation.

    `buckets` is the sorted list of bucket *upper bounds*; samples above
    the last edge land in the +Inf overflow bucket.  `percentile(q)`
    interpolates linearly inside the bucket where the q-quantile falls,
    clamped to the observed [min, max] so tight distributions don't
    smear across a whole bucket.  O(len(buckets)) per percentile call,
    O(log len(buckets)) per observe — cheap enough for per-commit use.
    """

    __slots__ = ("edges", "counts", "count", "sum", "min", "max",
                 "exemplars")

    def __init__(self, buckets: Optional[Sequence[float]] = None):
        self.edges = sorted(float(b) for b in (buckets if buckets
                                               is not None
                                               else default_buckets()))
        assert self.edges, "a histogram needs at least one bucket edge"
        self.counts = [0] * (len(self.edges) + 1)   # +1 = +Inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        # per-bucket last (exemplar_id, value) pair or None — the
        # OpenMetrics exemplar model: a tail-latency bucket remembers a
        # trace span id, so a p99 sample in a scrape links back to the
        # exact traced commit that produced it
        self.exemplars: List[Optional[Tuple[object, float]]] = \
            [None] * (len(self.edges) + 1)

    def observe(self, v: float, exemplar: object = None) -> None:
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        self.counts[i] += 1
        if exemplar is not None:
            self.exemplars[i] = (exemplar, v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def percentile(self, q: float) -> Optional[float]:
        """Estimate the q-th percentile (q in [0, 100]) from buckets."""
        if not self.count:
            return None
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            lo_cum, cum = cum, cum + c
            if cum >= rank:
                # interpolate within this bucket between its edges,
                # using the observed extrema as the outermost bounds
                lo = self.edges[i - 1] if i > 0 else self.min
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo, hi = max(lo, self.min), min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - lo_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.max

    def summary(self) -> dict:
        return {"n": self.count,
                "p50": self.percentile(50),
                "p99": self.percentile(99),
                "mean": self.mean,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max}


LabelKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: dict) -> LabelKey:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class MetricsRegistry:
    """One namespace of metrics; `Pool` owns one per pool.

    Thread-light: a single lock guards child creation (hooks may fire
    from checkpoint threads); the hot-path mutations themselves are
    plain float ops on the returned child object, which call sites cache
    or re-fetch (a dict hit) as they prefer.
    """

    def __init__(self):
        self._metrics: Dict[LabelKey, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, labels: dict, cls, *args):
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(*args)
                    self._metrics[key] = m
        assert isinstance(m, cls), (
            f"metric {name!r} already registered as "
            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get(name, labels, Histogram, buckets)

    def labeled(self, **labels) -> "LabeledRegistry":
        """A view of this registry with `labels` bound onto every metric.

        The view quacks like a registry (counter/gauge/histogram/collect/
        snapshot), so a `Pool` handed `group_registry.labeled(tenant="t3")`
        publishes every series with a `tenant="t3"` label into the shared
        parent — per-tenant namespacing without any call-site changes —
        while `collect()`/`snapshot()` on the view see only that tenant's
        slice (what the per-tenant `stats()` embeds).
        """
        return LabeledRegistry(self, labels)

    # -- read side --------------------------------------------------------------

    def collect(self) -> Iterable[Tuple[str, dict, object]]:
        """Yield (name, labels_dict, metric) sorted by (name, labels)."""
        for (name, labels), m in sorted(self._metrics.items()):
            yield name, dict(labels), m

    def snapshot(self) -> dict:
        """Host-side dict snapshot (what `pool.stats()` embeds)."""
        out: dict = {}
        for name, labels, m in self.collect():
            lkey = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            cell = out.setdefault(name, {})
            if isinstance(m, (Counter, Gauge)):
                cell[lkey] = m.value
            else:
                cell[lkey] = m.summary()
        return out


class LabeledRegistry:
    """Label-binding view over a `MetricsRegistry` (see `labeled`).

    Writes go to the parent with the bound labels merged in (explicit
    labels win on key collision is deliberately NOT supported: a bound
    label is an identity, so rebinding it from a call site is a bug and
    asserts).  Reads (`collect`/`snapshot`) filter the parent down to
    metrics carrying every bound label and strip those labels from the
    result, so a tenant's snapshot looks exactly like a private
    registry's.
    """

    def __init__(self, base: MetricsRegistry, labels: dict):
        self.base = base
        self.labels = {k: str(v) for k, v in labels.items()}

    def _merge(self, labels: dict) -> dict:
        clash = set(self.labels) & set(labels)
        assert not clash, f"label(s) {sorted(clash)} already bound"
        return {**self.labels, **labels}

    def counter(self, name: str, **labels) -> Counter:
        return self.base.counter(name, **self._merge(labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self.base.gauge(name, **self._merge(labels))

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self.base.histogram(name, buckets, **self._merge(labels))

    def labeled(self, **labels) -> "LabeledRegistry":
        return LabeledRegistry(self.base, self._merge(labels))

    def collect(self) -> Iterable[Tuple[str, dict, object]]:
        bound = set(self.labels.items())
        for name, labels, m in self.base.collect():
            if bound <= set(labels.items()):
                yield name, {k: v for k, v in labels.items()
                             if k not in self.labels}, m

    snapshot = MetricsRegistry.snapshot
