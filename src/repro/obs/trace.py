"""Span tracing: structured JSONL events that make a campaign replayable.

The trace vocabulary is deliberately tiny — three event shapes, one id
space:

  * point events   {"ev": "point", "kind": ..., "id": N, "ts": ..., ...}
    — one-shot facts.  Fault injections are points with kind="fault";
    their ids are the linkage currency.
  * span begin     {"ev": "begin", "kind": ..., "id": N, "ts": ..., ...}
  * span end       {"ev": "end",   "kind": ..., "id": N, "ts": ..., ...}
    — an interval (recovery solve, scrub, rescale, flush).  Recovery
    spans carry `faults=[fault ids]`, tying every injected fault to the
    recovery that resolved it — and `followups` recoveries drained from
    the re-entry queue open their own spans against the same id space,
    so a chaos campaign becomes one connected, replayable timeline.

Ids are monotonically increasing per tracer; `ts` is host
perf_counter-relative seconds (monotonic within one trace — the point
is ordering and duration, not wall-clock epoch).  With a `path`, every
event is appended to the JSONL file as it happens (crash traces stay
useful); the in-memory `events` list always accumulates, which is what
tests and `validate_events` consume.

`validate_events` is the single source of truth for trace well-formedness
— scripts/trace_check.py is a thin CLI over it:
  * every span begin has exactly one matching end (same id);
  * every fault event id is referenced by >= 1 resolving span (a
    recovery, or a scrub whose repair fixed the damage);
  * no span references an unknown fault id (no orphan links).
"""
from __future__ import annotations

import json
import os
import time
from typing import IO, List, Optional


class Tracer:
    """Append-only structured event stream (host-side, jax-free).

    With `rotate_lines` / `rotate_bytes`, the JSONL output is rotated
    into numbered segments (`trace-0001.jsonl`, `trace-0002.jsonl`, …
    derived from `path`) once a segment reaches either threshold, so a
    long-soak or multi-tenant run never grows one file unbounded.  A
    span may begin in one segment and end in the next — segments are a
    storage artifact, not a semantic boundary — which is why
    scripts/trace_check.py validates a rotated family as ONE logical
    event stream.  The in-memory `events` list is unaffected by
    rotation; `segments` lists the files written so far.
    """

    def __init__(self, path: Optional[str] = None, *,
                 rotate_lines: Optional[int] = None,
                 rotate_bytes: Optional[int] = None):
        assert rotate_lines is None or rotate_lines > 0
        assert rotate_bytes is None or rotate_bytes > 0
        self.path = path
        self.rotate_lines = rotate_lines
        self.rotate_bytes = rotate_bytes
        self.segments: List[str] = []
        self.events: List[dict] = []
        self._next_id = 0
        self._t0 = time.perf_counter()
        self._fh: Optional[IO] = None
        self._seg_lines = 0
        self._seg_bytes = 0
        if path is not None:
            self._fh = open(self._target(), "a", buffering=1)  # line-buffered

    @property
    def _rotating(self) -> bool:
        return self.rotate_lines is not None or self.rotate_bytes is not None

    def _target(self) -> str:
        if not self._rotating:
            self.segments.append(self.path)
            return self.path
        stem, ext = os.path.splitext(self.path)
        seg = f"{stem}-{len(self.segments) + 1:04d}{ext or '.jsonl'}"
        self.segments.append(seg)
        return seg

    def _maybe_rotate(self, line_bytes: int) -> None:
        if not (self._rotating and self._seg_lines > 0):
            return
        full = ((self.rotate_lines is not None
                 and self._seg_lines >= self.rotate_lines)
                or (self.rotate_bytes is not None
                    and self._seg_bytes + line_bytes > self.rotate_bytes))
        if full:
            self._fh.close()
            self._fh = open(self._target(), "a", buffering=1)
            self._seg_lines = 0
            self._seg_bytes = 0

    # -- emission ---------------------------------------------------------------

    def _write(self, event: dict) -> dict:
        self.events.append(event)
        if self._fh is not None:
            line = json.dumps(event) + "\n"
            self._maybe_rotate(len(line))
            self._fh.write(line)
            self._seg_lines += 1
            self._seg_bytes += len(line)
        return event

    def _fresh(self, ev: str, kind: str, fields: dict) -> dict:
        eid = self._next_id
        self._next_id += 1
        return {"ev": ev, "kind": kind, "id": eid,
                "ts": round(time.perf_counter() - self._t0, 6), **fields}

    def emit(self, kind: str, **fields) -> int:
        """One point event; returns its id (faults hand this to spans)."""
        return self._write(self._fresh("point", kind, fields))["id"]

    def begin(self, kind: str, **fields) -> int:
        """Open a span; close it with `end(span_id, ...)`."""
        return self._write(self._fresh("begin", kind, fields))["id"]

    def end(self, span_id: int, kind: str, **fields) -> None:
        self._write({"ev": "end", "kind": kind, "id": span_id,
                     "ts": round(time.perf_counter() - self._t0, 6),
                     **fields})

    def span(self, kind: str, **fields) -> "_Span":
        """Context manager: begin on enter, end on exit (an exception
        ends the span with error=<type> and propagates)."""
        return _Span(self, kind, fields)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class _Span:
    def __init__(self, tracer: Tracer, kind: str, fields: dict):
        self.tracer = tracer
        self.kind = kind
        self.fields = fields
        self.id: Optional[int] = None
        self.end_fields: dict = {}

    def annotate(self, **fields) -> None:
        """Attach fields to the span's end event."""
        self.end_fields.update(fields)

    def __enter__(self) -> "_Span":
        self.id = self.tracer.begin(self.kind, **self.fields)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.end_fields.setdefault("error", exc_type.__name__)
        self.tracer.end(self.id, self.kind, **self.end_fields)
        return False


# -- validation ----------------------------------------------------------------


def validate_events(events: List[dict]) -> List[str]:
    """Check trace well-formedness; returns violations ([] = valid)."""
    bad: List[str] = []
    begun: dict = {}
    ended: set = set()
    fault_ids: set = set()
    linked: set = set()
    for i, e in enumerate(events):
        ev, eid = e.get("ev"), e.get("id")
        if ev not in ("point", "begin", "end") or eid is None:
            bad.append(f"event {i}: malformed (ev={ev!r}, id={eid!r})")
            continue
        if ev == "point":
            if e.get("kind") == "fault":
                fault_ids.add(eid)
        elif ev == "begin":
            if eid in begun:
                bad.append(f"span {eid}: double begin")
            begun[eid] = e
        else:
            if eid not in begun:
                bad.append(f"span {eid}: end without begin")
            elif eid in ended:
                bad.append(f"span {eid}: double end")
            ended.add(eid)
        # any event carrying a `faults` list is a resolver — recovery
        # spans (begin carries the ids) and repairing-scrub span ends
        linked.update(e.get("faults") or ())
    for eid, e in begun.items():
        if eid not in ended:
            bad.append(f"span {eid} ({e.get('kind')}): never ended")
    for fid in sorted(fault_ids - linked):
        bad.append(f"fault {fid}: never linked to a recovery span")
    for fid in sorted(linked - fault_ids):
        bad.append(f"recovery links unknown fault id {fid} (orphan)")
    return bad


def load_jsonl(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
