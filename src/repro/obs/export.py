"""Prometheus text exposition of a MetricsRegistry.

Classic text format (the 0.0.4 exposition format every scraper parses):

    # TYPE pool_commits_total counter
    pool_commits_total 42
    # TYPE scrub_wall_ms histogram
    scrub_wall_ms_bucket{kind="full",le="1"} 3
    scrub_wall_ms_bucket{kind="full",le="+Inf"} 7
    scrub_wall_ms_sum{kind="full"} 12.5
    scrub_wall_ms_count{kind="full"} 7

Histogram buckets are cumulative (`le` = upper bound), as the format
requires.  Output is deterministic — metrics sorted by (name, labels),
values formatted canonically — so tests golden-diff it and a scrape
endpoint can serve it verbatim.  `write_metrics` is the --metrics-dir
launch-flag backend: one .prom text file plus a stats.json snapshot.
"""
from __future__ import annotations

import json
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry


def _fmt(v: float) -> str:
    """Canonical value formatting: integers bare, floats via repr."""
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels(labels: dict, extra: Optional[dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
    return "{" + body + "}"


def _exemplar(exemplars, i: int) -> str:
    """OpenMetrics exemplar suffix for bucket i, or '' when absent.

    Rendered as ` # {span_id="N"} value` — a scrape of a tail-latency
    bucket carries the trace span id of the exact sample that landed
    there, so a p99 commit links straight to its trace span
    (scripts/trace_check.py validates the linkage against the trace
    file).  Classic-format parsers treat the suffix as a comment, so
    the exposition stays 0.0.4-compatible.
    """
    if not exemplars or exemplars[i] is None:
        return ""
    eid, v = exemplars[i]
    return f' # {{span_id="{eid}"}} {_fmt(float(v))}'


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format."""
    lines = []
    typed: set = set()
    for name, labels, m in registry.collect():
        kind = ("counter" if isinstance(m, Counter)
                else "gauge" if isinstance(m, Gauge) else "histogram")
        if name not in typed:
            lines.append(f"# TYPE {name} {kind}")
            typed.add(name)
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{name}{_labels(labels)} {_fmt(m.value)}")
            continue
        cum = 0
        ex = getattr(m, "exemplars", None)
        for i, (edge, c) in enumerate(zip(m.edges, m.counts)):
            cum += c
            lines.append(f"{name}_bucket"
                         f"{_labels(labels, {'le': _fmt(edge)})} {cum}"
                         f"{_exemplar(ex, i)}")
        lines.append(f"{name}_bucket"
                     f"{_labels(labels, {'le': '+Inf'})} {m.count}"
                     f"{_exemplar(ex, len(m.edges))}")
        lines.append(f"{name}_sum{_labels(labels)} {_fmt(m.sum)}")
        lines.append(f"{name}_count{_labels(labels)} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_metrics(registry: MetricsRegistry, metrics_dir: str, *,
                  prefix: str = "pool",
                  stats: Optional[dict] = None) -> dict:
    """Write <prefix>.prom (+ optional <prefix>.stats.json) into
    `metrics_dir`; returns the paths written."""
    os.makedirs(metrics_dir, exist_ok=True)
    out = {}
    prom = os.path.join(metrics_dir, f"{prefix}.prom")
    with open(prom, "w") as f:
        f.write(prometheus_text(registry))
    out["prom"] = prom
    if stats is not None:
        sj = os.path.join(metrics_dir, f"{prefix}.stats.json")
        with open(sj, "w") as f:
            json.dump(stats, f, indent=1, default=str)
        out["stats"] = sj
    return out


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "0.0.0.0") -> ThreadingHTTPServer:
    """Serve `prometheus_text(registry)` over HTTP on a daemon thread.

    This is the live scrape endpoint behind the launchers'
    --metrics-port flag.  Stdlib-only and jax-free: the handler renders
    the registry fresh per GET (a dict walk over host floats), so it
    can run beside a busy commit loop without touching device state.
    Returns the running server; the bound port is
    `server.server_address[1]` (pass port=0 to let the OS pick, as the
    smoke tests do) and `server.shutdown()` stops it.
    """
    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            body = prometheus_text(registry).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):   # quiet: the launcher owns stdout
            pass

    server = ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="metrics-scrape")
    thread.start()
    return server
