"""CLI: `python -m repro.chaos [--smoke | --scenario NAME] [--seed N]`.

--smoke runs one short scenario end-to-end (scripts/smoke.sh's chaos
liveness probe); the default runs the four-scenario core campaign and
prints each scenario's latency/recovery summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.chaos")
    ap.add_argument("--smoke", action="store_true",
                    help="one short scenario (CI liveness probe)")
    ap.add_argument("--scenario", default=None,
                    help="run one named scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full-length runs (default: quick)")
    ap.add_argument("--trace-dir", default=None,
                    help="write per-scenario JSONL span traces here "
                         "(validated inline; scripts/trace_check.py "
                         "re-validates offline)")
    args = ap.parse_args(argv)

    # 8 host devices before the first backend use, like benchmarks/run.py
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    from repro.chaos import scenarios

    quick = not args.full
    if args.smoke:
        out = scenarios.run_scenario("midwindow_scribble_loss",
                                     quick=True, seed=args.seed,
                                     trace_dir=args.trace_dir)
        ok = bool(out.get("golden_exact"))
        line = {"scenario": out["scenario"], "golden_exact": ok,
                "recoveries": len(out["recoveries"]),
                "health": out["health"]["status"]}
        if "trace" in out:
            line["trace"] = out["trace"]["path"]
            line["trace_violations"] = out["trace"]["violations"]
            ok = ok and not out["trace"]["violations"]
        print(json.dumps(line))
        return 0 if ok else 1
    names = ([args.scenario] if args.scenario
             else [*scenarios.SCENARIOS, *scenarios.GROUP_SCENARIOS])
    rc = 0
    for name in names:
        out = scenarios.run_scenario(name, quick=quick, seed=args.seed,
                                     trace_dir=args.trace_dir)
        ok = bool(out.get("golden_exact"))
        if "trace" in out and out["trace"]["violations"]:
            ok = False
        rc |= 0 if ok else 1
        print(json.dumps({
            "scenario": name, "golden_exact": ok,
            "commit_ms": out["commit_ms"],
            "recovery_ms": out["recovery_ms"],
            "health": out["health"]["status"]}))
    return rc


if __name__ == "__main__":
    sys.exit(main())
