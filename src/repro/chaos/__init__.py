"""Chaos campaign: scripted fault scenarios under live traffic.

The recovery benchmarks time reconstruction on a *quiet* pool; real
incidents arrive mid-traffic — a rank dies between two commits of an
open window, a scribble lands while a rescale is in flight, losses
stack up faster than the syndrome budget refreshes.  This package
scripts those storms deterministically and measures what the paper's
headline claims look like *under load*:

  * `FaultSchedule` / `ChaosEvent` — a seeded, replayable timeline of
    faults and control events keyed to commit indices (schedule.py).
  * `PoolWorkload` — deterministic synthetic traffic over a `Pool`:
    an elementwise f32 step whose trajectory is bit-identical across
    mesh shapes, so every scenario can be diffed against a fault-free
    golden run (workload.py).
  * `ScenarioRunner` — drives the workload while the schedule fires,
    recording per-commit latency (clean vs during-disturbance) and
    recovery-under-load timings; ends with the golden bit-identity
    check (runner.py).
  * `scenarios` — the campaign: rescale under traffic, straggler
    degradation, mid-window scribble+loss, syndrome-budget exhaustion
    and re-arm, crash/replay storms over r x W (scenarios.py).
  * `attach_schedule` — runtime attachment: the same schedules ride on
    a live `Trainer`/`Server` through their step hooks (runner.py).

`python -m repro.chaos --smoke` runs one short scenario end-to-end
(CI's liveness probe); `benchmarks/chaos.py` runs the full campaign
and lands the numbers in BENCH_commit.json §chaos, gated by
scripts/bench_gate.py.
"""
# Lazy re-exports (PEP 562): `python -m repro.chaos` must be able to
# set XLA_FLAGS in __main__ before anything here drags jax in — the
# package import itself stays free of jax side effects.
_EXPORTS = {
    "ChaosEvent": ("repro.chaos.schedule", "ChaosEvent"),
    "FaultSchedule": ("repro.chaos.schedule", "FaultSchedule"),
    "PoolWorkload": ("repro.chaos.workload", "PoolWorkload"),
    "ScenarioRunner": ("repro.chaos.runner", "ScenarioRunner"),
    "attach_schedule": ("repro.chaos.runner", "attach_schedule"),
    "inject_event": ("repro.chaos.runner", "inject_event"),
    "scenarios": ("repro.chaos.scenarios", None),
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib
    mod = importlib.import_module(mod_name)
    return mod if attr is None else getattr(mod, attr)
