"""Seeded, replayable fault timelines.

A schedule is a list of `ChaosEvent`s keyed to commit indices.  Replay
determinism is the load-bearing property: the golden-run comparison only
means something if the same seed produces the same victims at the same
steps on every run, so event randomness (which rank dies, which words
get scribbled) is resolved by the seeded injectors in
runtime/failure.py — the schedule itself carries only *when* and *what
kind*, plus any pinned parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

# fault kinds route to runtime/failure.py injectors + Pool.recover;
# control kinds steer the runner (no state corruption of their own)
FAULT_KINDS = ("rank_loss", "multi_loss", "scribble")
CONTROL_KINDS = ("rescale", "straggler_start", "straggler_stop",
                 "snapshot")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled disturbance.

    step        commit index the event fires at (0-based)
    kind        one of FAULT_KINDS or CONTROL_KINDS
    mid_window  fault kinds only: fire at the engine's in-window
                arrival point (between a commit and its boundary
                flush) instead of between whole commits
    args        kind-specific pins, e.g. {"rank": 2} for rank_loss,
                {"ranks": [0, 3]} or {"e": 2} for multi_loss,
                {"n_words": 4} for scribble, {"shape": (8, 1)} for
                rescale, {"rank": 1, "factor": 6.0} for
                straggler_start.  Anything not pinned is drawn from
                the campaign seed deterministically.
    """
    step: int
    kind: str
    mid_window: bool = False
    args: tuple = ()     # sorted (key, value) pairs — hashable/frozen

    def __post_init__(self):
        if self.kind not in FAULT_KINDS + CONTROL_KINDS:
            raise ValueError(
                f"unknown chaos event kind {self.kind!r}; fault kinds "
                f"are {FAULT_KINDS}, control kinds {CONTROL_KINDS}")
        if self.mid_window and self.kind not in FAULT_KINDS:
            raise ValueError(
                f"mid_window only applies to fault kinds, not "
                f"{self.kind!r}")

    @staticmethod
    def make(step: int, kind: str, mid_window: bool = False,
             **args) -> "ChaosEvent":
        return ChaosEvent(step, kind, mid_window,
                          tuple(sorted(args.items())))

    @property
    def kw(self) -> dict:
        return dict(self.args)


class FaultSchedule:
    """An ordered, seeded timeline of ChaosEvents.

    `seed` salts every unpinned choice the events leave open; two
    schedules with the same events and seed replay identically (the
    injectors key their RNG off (seed, event index, kind)).
    """

    def __init__(self, events: Sequence[ChaosEvent], seed: int = 0):
        self.events: List[ChaosEvent] = sorted(
            events, key=lambda e: (e.step, e.kind))
        self.seed = int(seed)
        self._by_step: Dict[int, List[ChaosEvent]] = {}
        for ev in self.events:
            self._by_step.setdefault(ev.step, []).append(ev)

    def events_at(self, step: int) -> List[ChaosEvent]:
        return self._by_step.get(step, [])

    def event_seed(self, event: ChaosEvent) -> int:
        """The per-event sub-seed: stable under schedule replay."""
        return self.seed * 1_000_003 + self.events.index(event)

    @property
    def last_step(self) -> int:
        return self.events[-1].step if self.events else -1

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)
