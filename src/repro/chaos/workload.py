"""Deterministic synthetic traffic over a Pool.

The golden-run bit-identity check constrains the traffic generator
hard: the state trajectory must be (a) a pure function of (seed, step),
(b) bit-identical across mesh shapes (rescale under traffic must land
on the same bytes), and (c) cheap enough that per-commit latency is
dominated by the protection stack, not the "model".  An elementwise
f32 recurrence satisfies all three — elementwise ops have no
cross-shard reduction order to vary with sharding, so resharding the
state mid-run cannot perturb a single ulp.

The trainer/server runtimes are exercised by the schedule-attachment
path (runner.attach_schedule) instead: their loss-masked gradients are
deliberately NOT bit-identical under straggler drops, so they get
liveness + recovery assertions rather than golden diffs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ProtectConfig
from repro.pool import Pool

# the traffic recurrence: w <- w * GAIN + (step % PERIOD) * STEP_BIAS.
# GAIN keeps magnitudes stable over hundreds of steps; the bias term
# makes every step's output distinct (a stuck commit is visible).
GAIN = np.float32(1.0000001)
STEP_BIAS = np.float32(1e-6)
PERIOD = 7


def _initial_host_state(n_words: int, seed: int) -> np.ndarray:
    # Weyl-style integer mix — deterministic, seed-sensitive, no RNG
    # state to carry
    idx = np.arange(n_words, dtype=np.uint64)
    mixed = (idx * np.uint64(2654435761) + np.uint64(seed * 97 + 1))
    return ((mixed % np.uint64(1000003)).astype(np.float32)
            / np.float32(1000.0))


class PoolWorkload:
    """Sustained synthetic commit traffic against one protected pool."""

    def __init__(self, mesh, config: ProtectConfig, *,
                 n_bytes: int = 1 << 16, seed: int = 0,
                 straggler_policy=None):
        self.mesh = mesh
        self._mesh0 = mesh         # golden runs on the pre-rescale mesh
        self.config = config
        self.seed = int(seed)
        g = mesh.shape["data"]
        n = max(n_bytes // 4, g)
        self.n_words = (n + g - 1) // g * g
        self.specs = {"w": P("data")}
        host = {"w": _initial_host_state(self.n_words, self.seed)}
        state = self._put(host, mesh)
        # donate=False: the traffic step re-reads pool.state every
        # commit, and scenarios snapshot/restore freely
        self.pool = Pool.open(state, self.specs, mesh=mesh,
                              config=config, donate=False,
                              straggler_policy=straggler_policy)
        self.t = 0
        self._step_fn = jax.jit(
            lambda s, c: {"w": s["w"] * GAIN + c})

    def _put(self, host_state, mesh):
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), self.specs,
                          is_leaf=lambda x: isinstance(x, P))
        return jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s),
            host_state, sh)

    # -- traffic ----------------------------------------------------------------

    def bias(self, t: int) -> np.float32:
        return np.float32(t % PERIOD) * STEP_BIAS

    def traffic_step(self) -> bool:
        """One commit of traffic; blocks (latency measurements want the
        full commit on the clock) and returns the commit verdict."""
        new_state = self._step_fn(self.pool.state,
                                  jnp.float32(self.bias(self.t)))
        ok = self.pool.commit(new_state, data_cursor=self.t)
        jax.block_until_ready(self.pool.prot.state)
        self.t += 1
        return bool(jax.device_get(ok))

    # -- snapshot / restore / rescale -------------------------------------------

    def snapshot(self) -> dict:
        """Host copy of (state, t) — the checkpoint-tier stand-in."""
        self.pool.flush()
        return {"t": self.t,
                "state": jax.device_get(self.pool.state)}

    def restore(self, snap: dict) -> None:
        """Re-arm from a snapshot: fresh protection over restored bytes
        (the budget-exhausted path's checkpoint + re-protect)."""
        self.t = int(snap["t"])
        self.pool.init(self._put(snap["state"], self.mesh))

    def replay_to(self, t_target: int) -> None:
        """Deterministically re-run traffic up to step `t_target`."""
        while self.t < t_target:
            self.traffic_step()

    def rescale(self, shape) -> None:
        """Elastic resize under traffic: (data, model) mesh shape."""
        new_mesh = jax.make_mesh(tuple(shape), ("data", "model"))
        self.pool = self.pool.rescale(new_mesh)
        self.mesh = new_mesh

    # -- endings ----------------------------------------------------------------

    def final_host(self) -> dict:
        """Flushed host copy of the state (the golden-diff operand)."""
        self.pool.flush()
        return jax.device_get(self.pool.state)

    def golden(self, n_steps: int) -> dict:
        """The fault-free reference: same seed, same steps, no chaos —
        run on a fresh pool so nothing of this run leaks in."""
        ref = PoolWorkload(self._mesh0, self.config,
                           n_bytes=self.n_words * 4, seed=self.seed)
        for _ in range(n_steps):
            ref.traffic_step()
        return ref.final_host()
