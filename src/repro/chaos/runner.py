"""ScenarioRunner: drive traffic while a fault schedule fires.

Event timing model (one `step` = one committed transaction):

  * control events (`rescale`, `straggler_*`, `snapshot`) fire BEFORE
    step t's commit;
  * between-commit faults (mid_window=False) fire AFTER step t's commit
    returns, and are recovered before step t+1 dispatches — the window
    where a real SIGBUS lands relative to the commit loop;
  * mid-window faults (mid_window=True) fire INSIDE step t's commit at
    the engine's fault-arrival point (after the in-window commit,
    before any boundary flush), via `Pool.set_arrival_hook`.

Every commit's wall latency is recorded and classified clean vs
during-disturbance (within `disturb_steps` of any event), so the
campaign reports tail latency under chaos against the quiet baseline.
Recoveries are timed under the same load.  A recovery that raises the
syndrome-budget-exhausted error falls back to the checkpoint tier:
restore the last snapshot, re-protect, and deterministically replay the
missed traffic — the scenario still must end bit-identical to golden.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import numpy as np

from repro.chaos.schedule import FAULT_KINDS, ChaosEvent, FaultSchedule
from repro.chaos.workload import PoolWorkload
from repro.pool import Fault
from repro.runtime import failure


def _ms_summary(hist) -> dict:
    """Distill an obs Histogram into the campaign's record shape.

    The runner publishes every wall sample into the pool's metric
    registry (one telemetry plane for live pools and campaigns alike)
    and summarizes from there — the old private numpy percentile helper
    is gone; percentile estimates come from the registry's fixed
    buckets, interpolated and clamped to the observed extrema.
    """
    s = hist.summary()
    return {"n": s["n"], "p50_ms": s["p50"], "p99_ms": s["p99"]}


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))


def inject_event(protector, prot, event: ChaosEvent, seed: int):
    """Apply one fault event to a ProtectedState via the seeded
    injectors; returns (prot, FailureEvent)."""
    kw = event.kw
    if event.kind == "rank_loss":
        return failure.seeded_rank_loss(protector, prot, seed,
                                        rank=kw.get("rank"))
    if event.kind == "multi_loss":
        return failure.seeded_multi_rank_loss(
            protector, prot, seed, e=kw.get("e", 2),
            ranks=kw.get("ranks"))
    if event.kind == "scribble":
        return failure.seeded_scribble(
            protector, prot, seed, n_words=kw.get("n_words", 4),
            rank=kw.get("rank"))
    raise ValueError(f"not a fault kind: {event.kind!r}")


class ScenarioRunner:
    def __init__(self, workload: PoolWorkload, schedule: FaultSchedule,
                 *, disturb_steps: int = 3,
                 straggler_base_s: float = 0.01):
        self.wl = workload
        self.schedule = schedule
        self.disturb_steps = int(disturb_steps)
        # synthetic per-step duration fed to the straggler policy (the
        # dilation vector scales it); synthetic, not wall time, so
        # detection is as deterministic as the schedule
        self.straggler_base_s = float(straggler_base_s)

    # -- injection --------------------------------------------------------------

    def _inject_prot(self, prot, event: ChaosEvent):
        """Apply one fault event to a ProtectedState; (prot, event)."""
        return inject_event(self.wl.pool.protector, prot, event,
                            self.schedule.event_seed(event))

    @staticmethod
    def _combine(events: list) -> Fault:
        """Fold simultaneous fault events into one recovery request.

        A scribble concurrent with a rank loss is the overlap single
        parity cannot untangle (the survivors' XOR runs through the
        scribbled row): name every afflicted rank as a loss and solve
        through the syndrome stack — the documented escape hatch.
        """
        if len(events) == 1:
            return Fault.from_event(events[0])
        ranks: set = set()
        for ev in events:
            if ev.kind == "rank_loss":
                ranks.add(int(ev.lost_rank))
            elif ev.kind == "multi_loss":
                ranks.update(int(r) for r in ev.lost_ranks)
            elif ev.kind == "scribble":
                ranks.update(int(r) for r, _ in ev.locations)
            else:
                raise ValueError(ev.kind)
        if len(ranks) == 1:
            return Fault.rank_loss(ranks.pop())
        return Fault.multi_loss(*ranks)

    # -- the loop ---------------------------------------------------------------

    def run(self, n_steps: int, *, golden: bool = True) -> dict:
        wl, pool = self.wl, self.wl.pool
        snap = wl.snapshot()
        g0 = pool.protector.group_size
        slowdown = np.ones(g0)
        # one telemetry plane: every wall sample goes through the pool's
        # registry (which survives rescale — _open_kw threads it), and
        # the campaign record is distilled from the same histograms a
        # live monitoring scrape would read
        reg = pool.metrics
        h_clean = reg.histogram("chaos_commit_ms", phase="clean")
        h_during = reg.histogram("chaos_commit_ms", phase="during")
        h_disturb = reg.histogram("chaos_disturbance_ms")
        recoveries: List[dict] = []
        window_trace: List[tuple] = []
        disturbed = set()
        for e in self.schedule:
            disturbed.update(range(e.step,
                                   e.step + self.disturb_steps))

        t = 0
        while t < n_steps:
            evs = self.schedule.events_at(t)
            mid = [e for e in evs if e.mid_window]
            post = [e for e in evs
                    if e.kind in FAULT_KINDS and not e.mid_window]
            for e in evs:
                if e.kind == "rescale":
                    t0 = time.perf_counter()
                    wl.rescale(e.kw["shape"])
                    pool = wl.pool
                    ms = (time.perf_counter() - t0) * 1e3
                    h_disturb.observe(ms)
                    recoveries.append({
                        "step": t, "kind": "rescale", "ms": ms})
                    if pool.protector.group_size != g0:
                        g0 = pool.protector.group_size
                        slowdown = np.ones(g0)
                elif e.kind == "straggler_start":
                    slowdown[int(e.kw.get("rank", 0))] = float(
                        e.kw.get("factor", 6.0))
                elif e.kind == "straggler_stop":
                    slowdown[:] = 1.0
                elif e.kind == "snapshot":
                    snap = wl.snapshot()

            pend: list = []
            if mid:
                def _hook(prot, since, at_boundary, _mid=mid,
                          _pend=pend, _pool=pool):
                    out = prot
                    for e in _mid:
                        out, ev = self._inject_prot(out, e)
                        # the arrival hook bypasses pool.inject, so the
                        # fault must be noted explicitly to keep the
                        # trace linkage (fault id -> recovery span)
                        _pool.note_event(ev)
                        _pend.append(ev)
                    return out
                pool.set_arrival_hook(_hook)
            t0 = time.perf_counter()
            wl.traffic_step()
            dt_ms = (time.perf_counter() - t0) * 1e3
            (h_during if t in disturbed else h_clean).observe(dt_ms)
            if mid:
                pool.set_arrival_hook(None)

            if pool.straggler is not None:
                pool.observe_commit_times(
                    self.straggler_base_s * slowdown)
                window_trace.append(
                    (t, pool.engine.window if pool.engine else 1,
                     len(pool.dropped_replicas)))

            for e in post:
                ev = pool.inject(
                    lambda p, prot, _e=e: self._inject_prot(prot, _e))
                pend.append(ev)
            if pend:
                fault = self._combine(pend)
                t0 = time.perf_counter()
                try:
                    rep = pool.recover(fault)
                    jax.block_until_ready(pool.prot.state)
                    ms = (time.perf_counter() - t0) * 1e3
                    h_disturb.observe(ms)
                    rec = {"step": t, "ms": ms}
                    rec.update(rep.to_event())
                    recoveries.append(rec)
                except RuntimeError as err:
                    if "syndrome budget exhausted" not in str(err):
                        raise
                    # checkpoint-tier fallback: restore the snapshot,
                    # re-protect, replay the missed traffic exactly
                    wl.restore(snap)
                    wl.replay_to(t + 1)
                    ms = (time.perf_counter() - t0) * 1e3
                    h_disturb.observe(ms)
                    recoveries.append({
                        "step": t, "kind": "restore_replay", "ms": ms,
                        "error": str(err).splitlines()[0],
                        "replayed": t + 1 - snap["t"]})
            t += 1

        out = {
            "steps": n_steps,
            "events": len(self.schedule),
            "r": pool.redundancy,
            "window": self.wl.config.window,
            "commit_ms": {"clean": _ms_summary(h_clean),
                          "during": _ms_summary(h_during)},
            "recovery_ms": _ms_summary(h_disturb),
            "recoveries": recoveries,
            "stats": pool.stats(),
            "health": pool.health().to_dict(),
        }
        if window_trace:
            out["window_trace"] = {
                "min_window": min(w for _, w, _d in window_trace),
                "max_window": max(w for _, w, _d in window_trace),
                "max_dropped": max(d for _, _w, d in window_trace),
                "final_window": window_trace[-1][1],
                "final_dropped": window_trace[-1][2],
            }
        if golden:
            out["golden_exact"] = _trees_equal(wl.final_host(),
                                               wl.golden(n_steps))
        return out


def attach_schedule(host, schedule: FaultSchedule,
                    log: Optional[list] = None) -> list:
    """Ride a FaultSchedule on a live Trainer/Server via its step hook.

    Fault events inject into the host's pool and route through
    `Pool.recover` (between-commit timing: inject + recover after the
    step that matches the event index).  `straggler_start`/`_stop`
    dilate `host.replica_slowdown` when the host has one (the trainer's
    straggler feed).  Returns the log list; each fired event appends
    {"step", "kind", ...}.
    """
    log = log if log is not None else []
    counter = {"t": 0}

    def _hook(h, out) -> None:
        t = counter["t"]
        counter["t"] += 1
        pool = h.pool
        if pool is None:
            return
        for e in schedule.events_at(t):
            if e.kind in FAULT_KINDS:
                ev = pool.inject(
                    lambda p, prot, _e=e: inject_event(
                        p, prot, _e, schedule.event_seed(_e)))
                rep = pool.recover(Fault.from_event(ev))
                rec = {"step": t}
                rec.update(rep.to_event())
                log.append(rec)
            elif e.kind == "straggler_start" and hasattr(
                    h, "replica_slowdown"):
                h.replica_slowdown[int(e.kw.get("rank", 0))] = float(
                    e.kw.get("factor", 6.0))
                log.append({"step": t, "kind": e.kind})
            elif e.kind == "straggler_stop" and hasattr(
                    h, "replica_slowdown"):
                h.replica_slowdown[:] = 1.0
                log.append({"step": t, "kind": e.kind})
            else:
                raise ValueError(
                    f"runtime schedule attachment does not support "
                    f"{e.kind!r} events (use ScenarioRunner)")

    host.add_step_hook(_hook)
    return log
