"""The chaos campaign: named scenarios over the ScenarioRunner.

Each builder returns (workload, schedule, n_steps); `run_scenario`
executes one and `campaign` runs the whole set, which is what
benchmarks/chaos.py records into BENCH_commit.json §chaos and
scripts/bench_gate.py gates.  Every scenario ends with the golden
bit-identity check — chaos may cost latency, never bytes.

All scenarios run on the 8 host devices the benchmarks/tests force;
meshes are (4, 2) or (8, 1) so both zone geometries (G=4, G=8) see
traffic.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.chaos.runner import ScenarioRunner
from repro.chaos.schedule import ChaosEvent, FaultSchedule
from repro.chaos.workload import PoolWorkload
from repro.configs.base import ProtectConfig
from repro.obs import Tracer, validate_events

E = ChaosEvent.make


def _mesh(shape=(4, 2)):
    return jax.make_mesh(tuple(shape), ("data", "model"))


def _cfg(**kw) -> ProtectConfig:
    base = dict(mode="mlpc", window=4, redundancy=2, scrub_period=0)
    base.update(kw)
    return ProtectConfig(**base)


# -- builders: name -> (workload, schedule, n_steps) --------------------------


def rescale_under_traffic(quick: bool, seed: int):
    """Elastic (4,2) -> (8,1) -> (4,2) while commits keep flowing, with
    a rank loss landing right after the first rescale settles."""
    n = 24 if quick else 60
    wl = PoolWorkload(_mesh((4, 2)), _cfg(), n_bytes=1 << 15, seed=seed)
    sched = FaultSchedule([
        E(n // 4, "rescale", shape=(8, 1)),
        E(n // 4 + 2, "rank_loss"),
        E(n // 2, "rescale", shape=(4, 2)),
    ], seed=seed)
    return wl, sched, n


def straggler(quick: bool, seed: int):
    """One replica runs 6x slow mid-run: the policy drops it, the
    adaptive window collapses while degraded, and regrows after the
    replica heals."""
    from repro.dist.straggler import StragglerPolicy
    n = 36 if quick else 80
    cfg = _cfg(window=8, straggler_threshold=2.0,
               window_growth_commits=4)
    mesh = _mesh((4, 2))
    policy = StragglerPolicy(mesh.shape["data"], threshold=2.0,
                             window=4)
    wl = PoolWorkload(mesh, cfg, n_bytes=1 << 15, seed=seed,
                      straggler_policy=policy)
    sched = FaultSchedule([
        E(n // 4, "straggler_start", rank=1, factor=6.0),
        E(n // 2, "straggler_stop"),
    ], seed=seed)
    return wl, sched, n


def midwindow_scribble_loss(quick: bool, seed: int):
    """A scribble on one rank concurrent with another rank's loss,
    both landing INSIDE an open window — the overlap single parity
    cannot untangle; the r=2 syndrome stack solves both as losses."""
    n = 20 if quick else 48
    wl = PoolWorkload(_mesh((4, 2)), _cfg(window=8), n_bytes=1 << 15,
                      seed=seed)
    sched = FaultSchedule([
        E(n // 2, "scribble", mid_window=True, rank=0, n_words=6),
        E(n // 2, "rank_loss", mid_window=True, rank=2),
    ], seed=seed)
    return wl, sched, n


def budget_exhaust_rearm(quick: bool, seed: int):
    """Back-to-back losses beyond the stack: e=2 on an r=1 pool raises
    the budget-exhausted error, the runner restores + replays from the
    snapshot tier, and a later single loss again recovers online."""
    n = 24 if quick else 48
    wl = PoolWorkload(_mesh((4, 2)), _cfg(redundancy=1, window=2),
                      n_bytes=1 << 15, seed=seed)
    sched = FaultSchedule([
        E(n // 4, "snapshot"),
        E(n // 3, "multi_loss", e=2),           # e > r: exhausted
        E(2 * n // 3, "rank_loss"),             # re-armed: online again
    ], seed=seed)
    return wl, sched, n


def crash_replay_storm(r: int, window: int):
    """One storm cell: an e=r loss (the stack's full budget) plus a
    mid-window single loss, at syndrome height r and window W."""
    def build(quick: bool, seed: int):
        n = 16 if quick else 40
        g = 8 if r >= 4 else 4          # r <= G - 1
        shape = (8, 1) if g == 8 else (4, 2)
        wl = PoolWorkload(_mesh(shape),
                          _cfg(redundancy=r, window=window),
                          n_bytes=1 << 15, seed=seed)
        events = [E(n // 3, "rank_loss", mid_window=(window > 1))]
        if r >= 2:
            events.append(E(2 * n // 3, "multi_loss", e=r))
        return wl, FaultSchedule(events, seed=seed), n
    return build


def multi_tenant_interference(quick: bool, seed: int,
                              trace_dir: Optional[str] = None) -> dict:
    """Interference under multi-tenancy: a PoolGroup of four same-cohort
    tenants commits batched waves while tenant 0 is scribbled, put
    through a quarantined recovery, and the shared scrub scheduler
    keeps one-pool-per-wave verification pressure on the whole group.
    The neighbors must (a) end bit-identical to a fault-free reference
    group run (chaos costs latency, never bytes — for ANY tenant) and
    (b) keep committing through the victim's quarantine window.  The
    result carries baseline-vs-interference wave latency for the
    benchmark tier; the golden check is what campaigns gate on.
    """
    import time as _time

    import numpy as np

    from repro.pool import Fault
    from repro.runtime import failure
    from repro.tenancy import PoolGroup

    n = 24 if quick else 60
    n_t = 4
    mesh = _mesh((4, 2))
    cfg = _cfg(window=1)                      # sync: one dispatch/wave
    step_fn = jax.jit(lambda s, c: {"w": s["w"] * 1.0000001 + c})

    def build_group(tracer=None):
        grp = PoolGroup(mesh, scrub_page_budget=0,
                        tracer=tracer if tracer is not None else None)
        states = {}
        for t in range(n_t):
            wl = PoolWorkload(mesh, cfg, n_bytes=1 << 14,
                              seed=seed + 13 * t)
            states[f"t{t}"] = wl.pool.state
            grp.admit(f"t{t}", wl.pool.state, wl.specs, config=cfg)
        return grp, states

    tracer = None
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = Tracer(os.path.join(
            trace_dir, "multi_tenant_interference.trace.jsonl"))
    grp, states = build_group(tracer)
    ref, ref_states = build_group()

    def wave(g, st, i, interfere: bool) -> float:
        ups = {tid: step_fn(g[tid].pool.state,
                            jax.numpy.float32((i % 7) * 1e-6))
               for tid in st}
        t0 = _time.perf_counter()
        g.commit(ups, data_cursor=i)
        jax.block_until_ready(g["t1"].pool.prot.state)
        wall = (_time.perf_counter() - t0) * 1e3
        if interfere:
            budget = g["t0"].pool.scrubber.pool_pages
            g.scrub_tick(page_budget=budget)
        return wall

    base_ms, intf_ms, recoveries = [], [], []
    for i in range(n):
        interfere = n // 3 <= i < 2 * n // 3
        (intf_ms if interfere else base_ms).append(
            wave(grp, states, i, interfere))
        wave(ref, ref_states, i, False)
        if i == n // 3:
            # scribble t0 mid-campaign; the quarantined recovery runs
            # while the other three tenants' traffic keeps flowing
            grp["t0"].pool.inject(
                lambda p, pr: failure.inject_scribble(
                    p, pr, rank=1, word_offsets=range(6)))
            t_r = _time.perf_counter()
            rep = grp.recover("t0", Fault.scribble(1, [0]))
            recoveries.append({
                "kind": "scribble", "tenant": "t0",
                "verified": bool(rep.verified),
                "ms": (_time.perf_counter() - t_r) * 1e3})

    golden = True
    for tid in states:
        a = jax.device_get(grp[tid].pool.state)
        b = jax.device_get(ref[tid].pool.state)
        golden &= all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    def _pct(xs, q):
        return float(np.percentile(np.asarray(xs), q)) if xs else None

    rec_ms = [r["ms"] for r in recoveries]
    # the campaign-standard result shape (benchmarks/chaos.py _row and
    # the §chaos gate consume it uniformly): clean = waves with no
    # scrub-storm pressure, during = waves inside the storm+quarantine
    # interference window
    out = {
        "scenario": "multi_tenant_interference",
        "golden_exact": bool(golden),
        "steps": n,
        "events": len(recoveries),
        "r": cfg.redundancy,
        "window": cfg.window,
        "tenants": n_t,
        "quarantined_during_run": True,
        "commit_ms": {
            "clean": {"p50_ms": _pct(base_ms, 50),
                      "p99_ms": _pct(base_ms, 99)},
            "during": {"p50_ms": _pct(intf_ms, 50),
                       "p99_ms": _pct(intf_ms, 99)}},
        "recovery_ms": {"p50_ms": _pct(rec_ms, 50),
                        "p99_ms": _pct(rec_ms, 99)},
        "recoveries": recoveries,
        "scheduler": grp.scheduler.stats(),
        "health": grp.health(),
    }
    if tracer is not None:
        out["trace"] = {"path": tracer.path,
                        "events": len(tracer.events),
                        "violations": validate_events(tracer.events)}
        tracer.close()
    return out


def fault_with_inflight_commits(quick: bool, seed: int,
                                trace_dir: Optional[str] = None) -> dict:
    """Faults landing while the commit ring holds unresolved tickets.

    The async pipeline (PR 10) dispatches commit t+k before commit t's
    verdict resolves; this scenario injects a rank loss with k=2
    tickets in flight and a scribble with k=depth (a full ring) in
    flight, on a deferred-window pool at pipeline_depth=4.  Recovery
    must (a) drain the ring deterministically — every in-flight ticket
    resolves, in dispatch order, before reconstruction touches the
    state — and (b) end golden-exact against a fault-free reference
    that resolved every commit synchronously: the pipeline may only
    ever reorder verdict *fetches*, never commit effects.
    """
    import time as _time

    import jax.numpy as jnp
    import numpy as np

    from repro.pool import Fault
    from repro.runtime import failure

    n = 24 if quick else 60
    depth = 4
    mesh = _mesh((4, 2))
    cfg = _cfg(window=4, pipeline_depth=depth)
    wl = PoolWorkload(mesh, cfg, n_bytes=1 << 15, seed=seed)
    ref = PoolWorkload(mesh, cfg, n_bytes=1 << 15, seed=seed)

    tracer = None
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = Tracer(os.path.join(
            trace_dir, "fault_with_inflight_commits.trace.jsonl"))
        wl.pool.set_tracer(tracer)

    def dispatch_async(w) -> tuple:
        """One commit of traffic dispatched through the ring — the
        verdict stays unresolved (PoolWorkload.traffic_step's async
        twin; same state recurrence, so golden comparison holds)."""
        new_state = w._step_fn(w.pool.state, jnp.float32(w.bias(w.t)))
        t0 = _time.perf_counter()
        tkt = w.pool.commit_async(new_state, data_cursor=w.t)
        wall = (_time.perf_counter() - t0) * 1e3
        w.t += 1
        return tkt, wall

    # fault step -> (fault kind, tickets to leave unresolved at injection)
    inflight_at = {n // 3: ("rank_loss", 2),
                   2 * n // 3: ("scribble", depth)}
    tickets, recoveries = [], []
    base_ms, during_ms = [], []
    hot = set()                      # steps whose dispatch rode a recovery
    for f in inflight_at:
        hot.update(range(f, min(f + 3, n)))
    i = 0
    while i < n:
        if i in inflight_at:
            kind, k = inflight_at[i]
            # build EXACTLY k unresolved tickets: drain to empty, then
            # dispatch k commits without touching a verdict
            wl.pool.drain()
            burst = []
            for _ in range(k):
                tkt, wall = dispatch_async(wl)
                ref.traffic_step()
                burst.append(tkt)
                during_ms.append(wall)
                i += 1
            assert wl.pool.in_flight == k, (wl.pool.in_flight, k)
            if kind == "rank_loss":
                wl.pool.inject(lambda p, pr: failure.inject_rank_loss(
                    p, pr, rank=1))
                fault = Fault.rank_loss(1)
            else:
                wl.pool.inject(lambda p, pr: failure.inject_scribble(
                    p, pr, rank=2, word_offsets=range(6)))
                fault = Fault.scribble(2, [0])
            t_r = _time.perf_counter()
            rep = wl.pool.recover(fault)
            rec_wall = (_time.perf_counter() - t_r) * 1e3
            # the recovery boundary drained the ring: every ticket the
            # fault caught in flight resolved, deterministically True
            # (the commits themselves were clean — only the state was
            # corrupted afterwards)
            assert all(t.resolved for t in burst), \
                "recovery left tickets unresolved"
            assert all(t.result() for t in burst)
            assert wl.pool.in_flight == 0
            recoveries.append({
                "kind": kind, "inflight_at_fault": k,
                "verified": bool(rep.verified), "ms": rec_wall})
            tickets += burst
        else:
            tkt, wall = dispatch_async(wl)
            ref.traffic_step()
            (during_ms if i in hot else base_ms).append(wall)
            tickets.append(tkt)
            i += 1
    wl.pool.drain()
    wl.pool.flush()
    ref.pool.flush()
    assert all(t.resolved and t.result() for t in tickets)

    golden = all(
        np.array_equal(np.asarray(jax.device_get(a)),
                       np.asarray(jax.device_get(b)))
        for a, b in zip(jax.tree.leaves(wl.pool.state),
                        jax.tree.leaves(ref.pool.state)))

    def _pct(xs, q):
        return float(np.percentile(np.asarray(xs), q)) if xs else None

    rec_ms = [r["ms"] for r in recoveries]
    out = {
        "scenario": "fault_with_inflight_commits",
        "golden_exact": bool(golden),
        "steps": n,
        "events": len(recoveries),
        "r": cfg.redundancy,
        "window": cfg.window,
        "pipeline_depth": depth,
        "commit_ms": {
            "clean": {"p50_ms": _pct(base_ms, 50),
                      "p99_ms": _pct(base_ms, 99)},
            "during": {"p50_ms": _pct(during_ms, 50),
                       "p99_ms": _pct(during_ms, 99)}},
        "recovery_ms": {"p50_ms": _pct(rec_ms, 50),
                        "p99_ms": _pct(rec_ms, 99)},
        "recoveries": recoveries,
        "health": wl.pool.health().to_dict(),
    }
    if tracer is not None:
        out["trace"] = {"path": tracer.path,
                        "events": len(tracer.events),
                        "violations": validate_events(tracer.events)}
        tracer.close()
    return out


SCENARIOS: Dict[str, Callable] = {
    "rescale_under_traffic": rescale_under_traffic,
    "straggler": straggler,
    "midwindow_scribble_loss": midwindow_scribble_loss,
    "budget_exhaust_rearm": budget_exhaust_rearm,
}

# group scenarios run their own loop (a PoolGroup is not a single-pool
# workload) but return the same result-dict shape the campaign gates
GROUP_SCENARIOS: Dict[str, Callable] = {
    "multi_tenant_interference": multi_tenant_interference,
    "fault_with_inflight_commits": fault_with_inflight_commits,
}

# the storm matrix is bench-only by default (r x W cells); the four
# named scenarios above are the gated core set
STORM_CELLS: Tuple[Tuple[int, int], ...] = (
    (1, 1), (2, 16), (3, 16), (4, 16))


def _run(wl, sched, n: int, name: str,
         trace_dir: Optional[str]) -> dict:
    """Execute one built scenario, optionally with a file-backed trace.

    With `trace_dir`, the workload's pool emits every fault/recovery/
    scrub/rescale event into <trace_dir>/<name>.trace.jsonl, and the
    result carries the trace's validation verdict (obs.validate_events
    — the same check scripts/trace_check.py runs offline): a campaign
    whose trace does not link every fault to its recovery is reported
    broken right where it ran.
    """
    tracer = None
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = Tracer(os.path.join(trace_dir,
                                     f"{name}.trace.jsonl"))
        wl.pool.set_tracer(tracer)
    out = ScenarioRunner(wl, sched).run(n)
    out["scenario"] = name
    if tracer is not None:
        out["trace"] = {"path": tracer.path,
                        "events": len(tracer.events),
                        "violations": validate_events(tracer.events)}
        tracer.close()
    return out


def run_scenario(name: str, *, quick: bool = True, seed: int = 0,
                 trace_dir: Optional[str] = None) -> dict:
    if name in GROUP_SCENARIOS:
        return GROUP_SCENARIOS[name](quick, seed, trace_dir)
    wl, sched, n = SCENARIOS[name](quick, seed)
    return _run(wl, sched, n, name, trace_dir)


def run_storm_cell(r: int, window: int, *, quick: bool = True,
                   seed: int = 0,
                   trace_dir: Optional[str] = None) -> dict:
    wl, sched, n = crash_replay_storm(r, window)(quick, seed)
    return _run(wl, sched, n, f"storm_r{r}_w{window}", trace_dir)


def campaign(*, quick: bool = True, seed: int = 0,
             storms: bool = True,
             trace_dir: Optional[str] = None) -> list:
    """The full campaign: the four core scenarios plus the storm
    matrix.  Raises if any scenario fails the golden bit-identity
    check — a chaos campaign whose end state drifted measured nothing —
    or (with `trace_dir`) emits a trace that fails validation.
    """
    results = [run_scenario(name, quick=quick, seed=seed,
                            trace_dir=trace_dir)
               for name in (*SCENARIOS, *GROUP_SCENARIOS)]
    if storms:
        cells = STORM_CELLS[:2] if quick else STORM_CELLS
        results += [run_storm_cell(r, w, quick=quick, seed=seed,
                                   trace_dir=trace_dir)
                    for r, w in cells]
    bad = [r["scenario"] for r in results if not r.get("golden_exact")]
    if bad:
        raise AssertionError(
            f"chaos scenarios ended non-golden: {bad} — recovered "
            "state must be bit-identical to the fault-free run")
    broken = [r["scenario"] for r in results
              if r.get("trace", {}).get("violations")]
    if broken:
        raise AssertionError(
            f"chaos traces failed validation: {broken} — every fault "
            "must link to the recovery span that resolved it")
    return results
